//! Speculative beam search over planner suggestions — the widened form
//! of Algorithm 1 (ROADMAP "candidate-level parallel rounds").
//!
//! The paper's loop is greedy: one suggestion applied, tested and
//! profiled per round. With validation cheap and thread-safe (PR 1),
//! the coordinator can afford to *speculate*: each round, every beam
//! state hands its top-K planner suggestions to the coding agent, all
//! materialized candidates validate + profile concurrently on scoped
//! workers, and the best `beam_width` states survive into the next
//! round. Related systems (STARK, CUDA Agent in PAPERS.md) report the
//! same widening as the main scaling lever for agentic kernel search.
//!
//! Determinism contract — the paper-fidelity tests depend on it:
//!
//! * planning and candidate materialization stay **serial** (the planner
//!   is a stateful policy; its stream must not depend on thread timing);
//! * each candidate's fumble roll comes from a **derived per-candidate
//!   PRNG stream** ([`candidate_stream`]) keyed by (round, state,
//!   candidate), never from a shared sequential stream;
//! * evaluation results merge **by candidate index**, and next-beam
//!   selection is a deterministic sort (score, then freshness, then
//!   parent/candidate index) with kernel-equality dedup;
//! * at `beam_width = 1, candidates_per_round = 1` the engine reproduces
//!   the greedy trajectory **bit-for-bit**
//!   ([`super::run::optimize_greedy`] is kept as the differential
//!   oracle, the way `interp::reference` backs the compiled machine).
//!
//! Acceptance mirrors the greedy gate per candidate (pass + no geomean
//! regression beyond [`ACCEPT_THRESHOLD`] vs the global best at round
//! start). A state that accepts a candidate is *replaced* by it (the
//! greedy sideways-move semantics); a state whose candidates all fail
//! survives with its per-state blocked-move set grown by this round's
//! non-improving moves. Blocked sets are **per state** and reset when a
//! candidate is accepted: the kernel changed, so a previously
//! non-improving move may pay again (the greedy loop kept stale blocks
//! forever — a bug this module fixes for both engines).

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::agents::{
    priority_gap, CodingAgent, MockLlm, PlannerPolicy, ProfileReport,
    ProfilingAgent, SingleAgentPlanner, Suggestion, TestQuality, TestReport,
    TestSuite, TestingAgent,
};
use crate::faults::{self, FaultKind, FaultPlan, FaultSite, FaultStats};
use crate::interp::budget::{
    join3, panic_message, run_indexed_catching,
};
use crate::interp::{kernel_hash, CompileCache, WorkerBudget};
use crate::ir::{printer, Kernel};
use crate::kernels::KernelSpec;
use crate::sim;
use crate::store::{EvalSlot, Store};
use crate::transforms::Move;
use crate::util::Prng;

use super::run::{
    AgentMode, Config, Outcome, RoundRecord, ACCEPT_THRESHOLD,
};

/// One live beam state: a known-good kernel plus the signals the planner
/// reads and the moves measured non-improving *for this kernel*.
#[derive(Clone)]
pub(crate) struct BeamState {
    pub(crate) kernel: Kernel,
    pub(crate) tests: TestReport,
    pub(crate) profile: ProfileReport,
    /// Internal geomean speedup vs the round-0 baseline.
    pub(crate) speedup: f64,
    /// Moves applied from the baseline to reach this kernel, in order —
    /// the trajectory the artifact store persists for warm starts.
    pub(crate) history: Vec<Move>,
    pub(crate) blocked: Vec<Move>,
    /// Consecutive rounds in which every kept candidate of this lineage
    /// failed validation (reset by any passing candidate). At
    /// [`Config::quarantine_after`] the lineage is quarantined: it
    /// stops planning and serves its known-good kernel.
    pub(crate) consec_failures: usize,
}

/// One materialized candidate awaiting evaluation.
pub(crate) struct Candidate {
    /// Beam state (parent) index.
    pub(crate) parent: usize,
    /// Candidate index within the parent (0 = the greedy choice).
    pub(crate) index: usize,
    pub(crate) kernel: Kernel,
    pub(crate) applied: Move,
    pub(crate) rationale: String,
}

/// Per-state materialization summary for one round.
#[derive(Clone)]
pub(crate) struct StateRound {
    /// Range into the round's candidate vector.
    pub(crate) start: usize,
    pub(crate) end: usize,
    /// Inapplicability reasons (reported when nothing materialized).
    pub(crate) reasons: Vec<String>,
    /// The state sat out this round under lineage quarantine.
    pub(crate) quarantined: bool,
}

/// Identity of one next-beam selection, in selection order — the
/// pipelined scheduler's commit check compares the selection a
/// speculated round was planned against with the selection the settled
/// round actually produced (`cand` is `usize::MAX` for a surviving
/// parent, mirroring [`PoolEntry::cand`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct SelectedId {
    pub(crate) parent: usize,
    pub(crate) cand: usize,
    pub(crate) fresh: bool,
}

/// Speculation ledger: lineages speculated across the round barrier by
/// the pipelined scheduler, and how each immediate-next speculation
/// fared when its basis round settled. Deterministic at every worker
/// count: exactly one entry per settled round that had a next-round
/// speculation registered, and registration is schedule-independent
/// (the basis results that gate it are complete before the round can
/// settle).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub(crate) struct SpecLedger {
    pub(crate) speculated: u64,
    pub(crate) committed: u64,
    pub(crate) aborted: u64,
}

/// Artifact-store ledger carried into the [`Outcome`] (all zero without
/// `--store`). The counters reflect disk state and I/O timing — they
/// are *excluded* from the byte-identity pins, which is exactly the
/// contract: store faults and corruption may shift these numbers, never
/// the shipped kernel or the search records.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub(crate) struct StoreLedger {
    pub(crate) hits: u64,
    pub(crate) misses: u64,
    pub(crate) corrupt: u64,
    pub(crate) resumed_rounds: u64,
}

/// A next-beam contender: an accepted candidate (fresh) or a surviving
/// parent.
struct PoolEntry {
    state: BeamState,
    score: f64,
    parent: usize,
    cand: usize,
    fresh: bool,
    /// Index of the candidate's `RoundRecord` (patched if selection
    /// drops it), `usize::MAX` for surviving parents.
    rec: usize,
}

/// Run telemetry carried into the [`Outcome`].
pub(crate) struct SearchTelemetry {
    pub(crate) candidates_evaluated: usize,
    pub(crate) peak_concurrent_evals: usize,
    /// Chosen K per planning event, in (round, state) order.
    pub(crate) k_per_round: Vec<usize>,
    /// Planning events where the adaptive scheduler shrank K.
    pub(crate) adaptive_k_rounds: usize,
    /// Candidates canonically abandoned by round cancellation.
    pub(crate) cancelled_candidates: usize,
    /// Fault telemetry summed canonically (per candidate, index order).
    pub(crate) fault_stats: FaultStats,
    /// Lineages that crossed the quarantine threshold this run.
    pub(crate) quarantined_lineages: u64,
    /// Cross-round speculation ledger (all zero for the barriered and
    /// greedy engines).
    pub(crate) speculation: SpecLedger,
    /// Artifact-store ledger (all zero without `--store`).
    pub(crate) store: StoreLedger,
}

/// Size one beam state's speculation width from the planner's priority
/// signal (ROADMAP "Adaptive K"): a flat ranking (normalized gap 0)
/// gets the full `candidates_per_round`, a gap at or beyond
/// `adaptive_gap_threshold` only `adaptive_min_candidates`, with linear
/// interpolation between. A threshold of 0 turns the shrink off
/// entirely — adaptive mode then reproduces the static schedule
/// bit-for-bit (no extra planner/PRNG traffic, differential-pinned).
fn adaptive_k(cfg: &Config, suggestions: &[Suggestion]) -> usize {
    let k_max = cfg.candidates_per_round.max(1);
    if !cfg.adaptive_candidates || cfg.adaptive_gap_threshold <= 0.0 {
        return k_max;
    }
    let k_min = cfg.adaptive_min_candidates.clamp(1, k_max);
    let frac = (priority_gap(suggestions) / cfg.adaptive_gap_threshold).min(1.0);
    let k = k_max as f64 - frac * (k_max - k_min) as f64;
    (k.round() as usize).clamp(k_min, k_max)
}

/// Counts in-flight candidate evaluations and remembers the peak — the
/// concurrency witness the beam tests read from the outcome.
#[derive(Default)]
pub(crate) struct ConcurrencyProbe {
    cur: AtomicUsize,
    peak: AtomicUsize,
}

impl ConcurrencyProbe {
    pub(crate) fn new() -> ConcurrencyProbe {
        ConcurrencyProbe {
            cur: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    pub(crate) fn enter(&self) -> ProbeGuard<'_> {
        let n = self.cur.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(n, Ordering::SeqCst);
        ProbeGuard { probe: self }
    }

    pub(crate) fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }
}

pub(crate) struct ProbeGuard<'a> {
    probe: &'a ConcurrencyProbe,
}

impl Drop for ProbeGuard<'_> {
    fn drop(&mut self) {
        self.probe.cur.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Derived PRNG stream for one speculative edit, stable in
/// (round, state, candidate) — independent of how many siblings
/// materialized before it, and shared verbatim with the greedy oracle
/// (which is always `(round, 0, 0)`).
pub(crate) fn candidate_stream(
    seed: u64,
    round: usize,
    state: usize,
    cand: usize,
) -> Prng {
    let tag = ((round as u64) << 32) ^ ((state as u64) << 16) ^ cand as u64;
    Prng::seed((seed ^ 0xC0DE).wrapping_add(tag.wrapping_mul(0x9E3779B97F4A7C15)))
}

/// Mode-appropriate planner policy (the LLM seam).
pub(crate) fn make_planner(cfg: &Config) -> Box<dyn PlannerPolicy> {
    match cfg.mode {
        AgentMode::Multi => Box::new(MockLlm::new(cfg.temperature, cfg.seed)),
        AgentMode::Single => {
            Box::new(SingleAgentPlanner::new(cfg.temperature, cfg.seed))
        }
    }
}

/// Bounded supervised attempts per agent call / candidate evaluation.
/// Backoff between attempts is *virtual*: the simulated clock has no
/// wall time to wait on, so the schedule is simply the capped,
/// deterministic attempt sequence keyed by attempt index.
pub(crate) const MAX_ATTEMPTS: usize = 3;

/// One candidate's supervised evaluation product: the verdict, the
/// profile, and the fault telemetry the canonical summation reads.
pub(crate) struct EvalProduct {
    pub(crate) tests: TestReport,
    pub(crate) profile: ProfileReport,
    pub(crate) stats: FaultStats,
}

/// Canonical report for a failure synthesized by the fault plane.
fn injected_report(msg: String) -> TestReport {
    TestReport {
        pass: false,
        max_rel_err: f32::INFINITY,
        max_abs_err: f32::INFINITY,
        failure: Some(msg),
        cases: 0,
        cancelled_cases: 0,
        round_cancelled: false,
    }
}

/// Canonical failed product for a candidate whose worker panicked: the
/// unwind was caught at the `run_indexed` fan-out boundary, the failure
/// is attributed to this candidate, and the (pure) profile still runs
/// so the record carries real measurements. Injected candidate panics
/// only ever fire on a first attempt, so the stats they abandon are
/// exactly `{injected: 1}` — recomputed here without replaying the
/// supervision loop.
pub(crate) fn panicked_product(
    profiler: &ProfilingAgent,
    kernel: &Kernel,
    suite: &TestSuite,
    base_profile: Option<&ProfileReport>,
    msg: &str,
) -> EvalProduct {
    EvalProduct {
        tests: injected_report(format!("worker panic: {msg}")),
        profile: profiler.profile(kernel, suite, base_profile),
        stats: FaultStats {
            injected: u64::from(msg == faults::candidate_panic_msg()),
            ..FaultStats::default()
        },
    }
}

/// AgentCall-site supervision around one coding-agent materialization:
/// injected transient agent failures are retried in place (serial, so
/// no schedule dependence) up to [`MAX_ATTEMPTS`]; exhaustion reports
/// the candidate as inapplicable with the injected reason.
pub(crate) fn supervised_agent_gate(
    plan: FaultPlan,
    key: u64,
    stats: &mut FaultStats,
) -> Result<(), String> {
    if !plan.enabled() {
        return Ok(());
    }
    let mut injected = 0u64;
    for attempt in 0..MAX_ATTEMPTS {
        if plan
            .roll(FaultSite::AgentCall, faults::mix(key, attempt as u64))
            .is_none()
        {
            stats.injected += injected;
            stats.survived += injected;
            return Ok(());
        }
        injected += 1;
        if attempt + 1 < MAX_ATTEMPTS {
            stats.retries += 1;
        }
    }
    stats.injected += injected;
    Err(faults::transient_agent_msg())
}

/// One supervised candidate evaluation: validation-site fault rolls,
/// bounded deterministic retry, watchdog-denominated hang conversion,
/// then the real validate + profile (with compile-/grid-level injection
/// keyed per attempt). Returns `None` only when a beam-round (or
/// speculative-lineage) token abandoned the validation or the profile
/// sweep (`cancel` is `Some`); injected candidate panics unwind to the
/// caller's `catch_unwind` boundary. The profile sweep polls the
/// round-level token too ([`ProfilingAgent::profile_cancellable`]), so
/// an abandoned lineage stops mid-sweep instead of profiling to
/// completion — any extra `None` this produces is normalized by the
/// canonical repair pass, which re-runs token-free.
///
/// `probes` is the pipelined scheduler's cache-probe ledger: each
/// attempt whose real validation runs records its attempt key, so a
/// committed speculative evaluation (which validated cache-free) can
/// replay exactly the compile-cache probes the cache-carrying barriered
/// evaluation would have made
/// ([`TestingAgent::replay_cache_probes`]). `None` everywhere else —
/// zero cost on the legacy paths.
///
/// With the plan disabled this is *exactly* today's evaluation — same
/// calls, same cache traffic — so fault-off runs stay bit-identical
/// (the differential walls are the oracle).
#[allow(clippy::too_many_arguments)]
pub(crate) fn evaluate_supervised(
    spec: &KernelSpec,
    cfg: &Config,
    tester: &TestingAgent,
    profiler: &ProfilingAgent,
    kernel: &Kernel,
    suite: &TestSuite,
    base_profile: Option<&ProfileReport>,
    cache: Option<&CompileCache>,
    cancel: Option<(&AtomicBool, &AtomicBool)>,
    probes: Option<&Mutex<Vec<u64>>>,
    key: u64,
) -> Option<EvalProduct> {
    let plan = cfg.fault;
    let validate = |agent: &TestingAgent| match cancel {
        Some((cand, rnd)) => {
            agent.validate_cancellable(spec, kernel, suite, cand, rnd)
        }
        None => agent.validate_with(spec, kernel, suite, cache),
    };
    let record_probe = |akey: u64| {
        if let Some(led) = probes {
            led.lock().expect("probe ledger poisoned").push(akey);
        }
    };
    let profile_or_cancel = || match cancel {
        Some((_, rnd)) => {
            profiler.profile_cancellable(kernel, suite, base_profile, rnd)
        }
        None => Some(profiler.profile(kernel, suite, base_profile)),
    };
    if !plan.enabled() {
        record_probe(key);
        let tests = validate(tester);
        if tests.round_cancelled {
            return None;
        }
        let profile = profile_or_cancel()?;
        return Some(EvalProduct {
            tests,
            profile,
            stats: FaultStats::default(),
        });
    }
    let mut stats = FaultStats::default();
    let mut last: Option<TestReport> = None;
    for attempt in 0..MAX_ATTEMPTS {
        if attempt > 0 {
            stats.retries += 1;
        }
        let akey = faults::mix(key, attempt as u64);
        if let Some(kind) = plan.roll(FaultSite::Validation, akey) {
            // Panics only fire on a first attempt (downgraded to
            // transients afterwards), so the stats a panic abandons are
            // always exactly {injected: 1} — recomputable at the
            // containment handler without replaying this loop.
            let kind = if attempt > 0 && kind == FaultKind::Panic {
                FaultKind::Transient
            } else {
                kind
            };
            stats.injected += 1;
            match kind {
                FaultKind::Panic => {
                    panic!("{}", faults::candidate_panic_msg())
                }
                FaultKind::Poison => {
                    // Terminal: a corrupted verdict is conservatively a
                    // failure (the gate can never flip fail → pass) and
                    // must not be retried into a laundered answer.
                    let profile = profile_or_cancel()?;
                    return Some(EvalProduct {
                        tests: injected_report(faults::poison_msg()),
                        profile,
                        stats,
                    });
                }
                FaultKind::Hang => {
                    stats.watchdog_trips += 1;
                    let steps = if cfg.watchdog_steps > 0 {
                        cfg.watchdog_steps
                    } else {
                        crate::interp::STEP_LIMIT
                    };
                    last = Some(injected_report(faults::hang_msg(steps)));
                    continue;
                }
                FaultKind::Transient => {
                    last = Some(injected_report(
                        faults::transient_validation_msg(),
                    ));
                    continue;
                }
            }
        }
        // Clean supervisor roll: the real validation runs, with
        // compile- and grid-level injection keyed to this attempt.
        record_probe(akey);
        let tests = validate(&tester.with_fault_context(plan, akey));
        if tests.round_cancelled {
            return None;
        }
        if let Some(f) = tests.failure.as_deref() {
            if faults::is_retryable(f) {
                stats.injected += 1;
                last = Some(tests);
                continue;
            }
            if faults::mentions_injection(f) {
                // Injected but terminal (a grid-worker panic caught at
                // the chunk join): canonical failed verdict as-is.
                stats.injected += 1;
                let profile = profile_or_cancel()?;
                return Some(EvalProduct {
                    tests,
                    profile,
                    stats,
                });
            }
        }
        // Real verdict. A profiling-sample fault retries the whole
        // attempt; a clean roll completes the evaluation, at which
        // point every injected fault along the way was survived.
        if plan.roll(FaultSite::Profiling, akey).is_some() {
            stats.injected += 1;
            last = Some(injected_report(faults::transient_profile_msg()));
            continue;
        }
        stats.survived = stats.injected;
        let profile = profile_or_cancel()?;
        return Some(EvalProduct {
            tests,
            profile,
            stats,
        });
    }
    // Retries exhausted: report the last injected failure. Nothing was
    // survived — the evaluation never completed cleanly.
    let tests =
        last.expect("the loop only falls through after a retryable fault");
    let profile = profile_or_cancel()?;
    Some(EvalProduct {
        tests,
        profile,
        stats,
    })
}

/// Post-processing shared by both engines (§3.2): oracle re-validation
/// and representative-shape measurement as three tasks over the
/// process-wide worker pool ([`join3`] — the caller is the first
/// worker, extra workers need budget tokens), then outcome assembly.
/// Routing the tail through the pool makes the `worker_budget` cap
/// exact for the whole run: no unbudgeted spawns remain
/// (witness-tested below).
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_outcome(
    spec: &KernelSpec,
    cfg: &Config,
    records: Vec<RoundRecord>,
    baseline: Kernel,
    best: Kernel,
    cache: &CompileCache,
    budget: &Arc<WorkerBudget>,
    telemetry: SearchTelemetry,
) -> Outcome {
    let shapes = spec.rep_shapes();
    let (final_correct, base_reports, best_reports) = join3(
        Some(budget.as_ref()),
        || {
            let final_tester =
                TestingAgent::new(TestQuality::Representative, cfg.seed ^ 0xFEED)
                    .with_grid_workers(cfg.grid_workers)
                    .with_worker_budget(Arc::clone(budget));
            let final_suite = final_tester.generate_tests(spec);
            final_tester
                .validate_with(spec, &best, &final_suite, Some(cache))
                .pass
        },
        || sim::profile_shapes(&cfg.model, &baseline, &shapes),
        || sim::profile_shapes(&cfg.model, &best, &shapes),
    );
    let per_shape: Vec<(String, f64, f64, f64)> = shapes
        .iter()
        .zip(base_reports.iter().zip(&best_reports))
        .map(|(d, (b, o))| {
            (
                spec.shape_label(d),
                b.total_us,
                o.total_us,
                b.total_us / o.total_us,
            )
        })
        .collect();
    let final_speedup = sim::geomean_speedup(&base_reports, &best_reports);
    let base_mean_us =
        base_reports.iter().map(|r| r.total_us).sum::<f64>() / shapes.len() as f64;
    let opt_mean_us =
        best_reports.iter().map(|r| r.total_us).sum::<f64>() / shapes.len() as f64;
    let cache_stats = cache.stats();

    Outcome {
        kernel_name: spec.paper_name.to_string(),
        mode: cfg.mode,
        records,
        baseline_loc: printer::loc(&baseline),
        best_loc: printer::loc(&best),
        baseline,
        best,
        final_speedup,
        per_shape,
        final_correct,
        base_mean_us,
        opt_mean_us,
        candidates_evaluated: telemetry.candidates_evaluated,
        peak_concurrent_evals: telemetry.peak_concurrent_evals,
        k_per_round: telemetry.k_per_round,
        adaptive_k_rounds: telemetry.adaptive_k_rounds,
        cancelled_candidates: telemetry.cancelled_candidates,
        cache_hits: cache_stats.hits,
        cache_misses: cache_stats.misses,
        faults_injected: telemetry.fault_stats.injected,
        faults_survived: telemetry.fault_stats.survived,
        retries: telemetry.fault_stats.retries,
        watchdog_trips: telemetry.fault_stats.watchdog_trips,
        quarantined_lineages: telemetry.quarantined_lineages,
        speculated_lineages: telemetry.speculation.speculated,
        committed_lineages: telemetry.speculation.committed,
        aborted_lineages: telemetry.speculation.aborted,
        store_hits: telemetry.store.hits,
        store_misses: telemetry.store.misses,
        store_corrupt_entries: telemetry.store.corrupt,
        resumed_rounds: telemetry.store.resumed_rounds,
    }
}

/// Open the run's artifact store from [`Config::store_dir`] with the
/// run's fault plan armed on every write. Best-effort: an unopenable
/// directory degrades to no store rather than failing the run.
pub(crate) fn open_store(cfg: &Config) -> Option<Arc<Store>> {
    let dir = cfg.store_dir.as_deref()?;
    match Store::open(std::path::Path::new(dir)) {
        Ok(s) => Some(Arc::new(s.with_faults(cfg.fault))),
        Err(_) => None,
    }
}

/// Journal identity of one `(kernel, search-config)` run: every knob
/// that shapes the search *trajectory*, and none that only schedules it
/// (grid workers, budgets, pipelining — byte-identical by the
/// differential walls) or happens after it (serving knobs). A killed
/// run and its `--resume` twin therefore agree on the key, as do
/// barriered and pipelined runs of the same search. `rounds` is
/// excluded on purpose: resuming with more rounds extends the run.
pub(crate) fn run_key(spec: &KernelSpec, cfg: &Config) -> u64 {
    crate::store::record_key(&[
        "run",
        spec.paper_name,
        &format!("{:?}", cfg.mode),
        &cfg.seed.to_string(),
        &cfg.bug_rate.to_bits().to_string(),
        &cfg.temperature.to_bits().to_string(),
        &cfg.beam_width.to_string(),
        &cfg.candidates_per_round.to_string(),
        &cfg.adaptive_candidates.to_string(),
        &cfg.adaptive_min_candidates.to_string(),
        &cfg.adaptive_gap_threshold.to_bits().to_string(),
        &cfg.round_budget.to_string(),
        &cfg.fault.rate.to_bits().to_string(),
        &cfg.fault.seed.to_string(),
        &cfg.fault.sites.to_string(),
        &cfg.watchdog_steps.to_string(),
        &cfg.quarantine_after.to_string(),
    ])
}

/// Store identity of one candidate validation: kernel structure, suite
/// identity (mode → test quality, seed) and the watchdog cap —
/// everything a verdict can depend on once live fault injection is
/// excluded (the eval-skip gate guarantees that).
fn eval_record_key(spec: &KernelSpec, cfg: &Config, khash: u64) -> u64 {
    crate::store::record_key(&[
        "eval",
        spec.paper_name,
        &format!("{khash:016x}"),
        &format!("{:?}", cfg.mode),
        &cfg.seed.to_string(),
        &cfg.watchdog_steps.to_string(),
    ])
}

/// Trajectory records key on the baseline's structural hash alone, so
/// any run of a structurally identical kernel — different config, more
/// rounds, another process — shares one best-known move sequence, and
/// a baseline change invalidates it automatically.
fn trajectory_key(baseline_hash: u64) -> u64 {
    crate::store::record_key(&["traj", &format!("{baseline_hash:016x}")])
}

/// Warm-start finish, shared by both engines: replay the store's best
/// recorded trajectory for this baseline and adopt the result only if
/// it is a *different* move sequence than the search found, applies
/// cleanly, validates, and measures strictly better — so a same-config
/// rerun (whose store already holds this run's own best history) is
/// byte-identical to a store-free run, while a warm start from a
/// richer earlier run lands its kernel as one macro-move. Finally
/// persists the winning trajectory (keep-best on the store side).
#[allow(clippy::too_many_arguments)]
pub(crate) fn warm_finish(
    s: &Store,
    spec: &KernelSpec,
    cfg: &Config,
    tester: &TestingAgent,
    profiler: &ProfilingAgent,
    cache: &CompileCache,
    suite: &TestSuite,
    baseline: &Kernel,
    base_profile: &ProfileReport,
    records: &mut Vec<RoundRecord>,
    best: &mut Kernel,
    best_speedup: &mut f64,
    best_history: &mut Vec<Move>,
) {
    let tkey = trajectory_key(kernel_hash(baseline));
    if let Some((moves, _stored)) = s.load_trajectory(tkey) {
        if moves != *best_history && !moves.is_empty() {
            let mut kernel = baseline.clone();
            let mut applies = true;
            for &m in &moves {
                match crate::transforms::apply(&kernel, m) {
                    Ok(k) => kernel = k,
                    Err(_) => {
                        applies = false;
                        break;
                    }
                }
            }
            if applies {
                let tests = tester.validate_with(spec, &kernel, suite, Some(cache));
                let profile = profiler.profile(&kernel, suite, Some(base_profile));
                let speedup = profile.speedup_vs_baseline;
                if tests.pass && speedup > *best_speedup {
                    let names: Vec<String> =
                        moves.iter().map(|m| m.name()).collect();
                    records.push(RoundRecord {
                        round: cfg.rounds + 1,
                        beam_state: 0,
                        candidate: 0,
                        applied: None,
                        rationale: String::new(),
                        pass: true,
                        speedup_internal: speedup,
                        mean_us_internal: profile.mean_us,
                        accepted: true,
                        loc: printer::loc(&kernel),
                        note: format!(
                            "warm-start: stored trajectory [{}] replayed at {:.2}x (internal)",
                            names.join(", "),
                            speedup
                        ),
                    });
                    *best = kernel;
                    *best_speedup = speedup;
                    *best_history = moves;
                }
            }
        }
    }
    if *best_speedup > 1.0 && !best_history.is_empty() {
        s.save_trajectory(tkey, best_history, *best_speedup);
    }
}

/// Fold the store's counters (plus the engine's replayed-round count)
/// into the telemetry ledger; all-zero without a store.
pub(crate) fn harvest_store(
    store: &Option<Arc<Store>>,
    resumed_rounds: u64,
) -> StoreLedger {
    match store {
        Some(s) => {
            let c = s.counters();
            StoreLedger {
                hits: c.hits,
                misses: c.misses,
                corrupt: c.corrupt,
                resumed_rounds,
            }
        }
        None => StoreLedger::default(),
    }
}

/// Replay one recorded attempt-probe sequence against the compile
/// cache — exact hit/miss parity with the validations the record
/// stands in for ([`TestingAgent::replay_cache_probes`]; each recorded
/// key is the attempt key whose real validation ran).
pub(crate) fn replay_probes(
    tester: &TestingAgent,
    cfg: &Config,
    kernel: &Kernel,
    suite: &TestSuite,
    cache: &CompileCache,
    keys: &[u64],
) {
    for &k in keys {
        if cfg.fault.enabled() {
            tester
                .with_fault_context(cfg.fault, k)
                .replay_cache_probes(kernel, suite, cache);
        } else {
            tester.replay_cache_probes(kernel, suite, cache);
        }
    }
}

/// Plan + materialize one round's candidates (serial; see module docs).
/// Shared verbatim by the barriered loop and the pipelined scheduler:
/// speculative rounds call it against a *predicted* next beam with a
/// snapshotted planner, so a committed speculation's plan — suggestion
/// stream, fumble rolls, adaptive-K choices — is byte-identical to the
/// plan the barriered engine would have made after the round settled.
#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_round(
    cfg: &Config,
    round: usize,
    beam: &[BeamState],
    planner: &mut dyn PlannerPolicy,
    coder: &CodingAgent,
    fault_stats: &mut FaultStats,
    k_per_round: &mut Vec<usize>,
    adaptive_k_events: &mut usize,
) -> (Vec<Candidate>, Vec<StateRound>) {
    let k_per_state = cfg.candidates_per_round.max(1);
    let mut cands: Vec<Candidate> = Vec::new();
    let mut per_state: Vec<StateRound> = Vec::with_capacity(beam.len());
    for (si, state) in beam.iter().enumerate() {
        if cfg.quarantine_after > 0
            && state.consec_failures >= cfg.quarantine_after
        {
            // Quarantined lineage: no planning, no speculation —
            // the state serves its known-good kernel and logs a
            // constant record below.
            per_state.push(StateRound {
                start: cands.len(),
                end: cands.len(),
                reasons: Vec::new(),
                quarantined: true,
            });
            continue;
        }
        let mut suggestions =
            planner.suggest(&state.kernel, &state.tests, &state.profile);
        suggestions.retain(|s| !state.blocked.contains(&s.mv));
        // Adaptive K (ROADMAP): spend the speculation budget where
        // the planner's ranking is contested, save it where one
        // move dominates. Static mode (or gap threshold 0) sizes
        // every event at the ceiling — bit-for-bit today's
        // behavior.
        let k_state = adaptive_k(cfg, &suggestions);
        debug_assert!(k_state <= k_per_state);
        k_per_round.push(k_state);
        if k_state < k_per_state {
            *adaptive_k_events += 1;
        }
        let start = cands.len();
        let mut reasons = Vec::new();
        for (pos, s) in suggestions.iter().enumerate() {
            let ci = cands.len() - start;
            if ci >= k_state {
                break;
            }
            // AgentCall-site supervision: transient coding-agent
            // faults retried in place (serial, keyed by candidate
            // slot and suggestion position — never by schedule).
            if let Err(reason) = supervised_agent_gate(
                cfg.fault,
                faults::mix(
                    faults::candidate_key(round, si, ci),
                    pos as u64,
                ),
                fault_stats,
            ) {
                reasons.push(reason);
                continue;
            }
            let mut stream = candidate_stream(cfg.seed, round, si, ci);
            match coder.apply_one(&state.kernel, s, &mut stream) {
                Ok(kernel) => cands.push(Candidate {
                    parent: si,
                    index: ci,
                    kernel,
                    applied: s.mv,
                    rationale: s.rationale.clone(),
                }),
                Err(e) => reasons.push(e),
            }
        }
        per_state.push(StateRound {
            start,
            end: cands.len(),
            reasons,
            quarantined: false,
        });
    }
    (cands, per_state)
}

/// The read-only evaluation context both engines thread through
/// [`settle_round`] (and the pipelined scheduler through its workers).
pub(crate) struct EvalEnv<'a> {
    pub(crate) spec: &'a KernelSpec,
    pub(crate) cfg: &'a Config,
    pub(crate) tester: &'a TestingAgent,
    pub(crate) profiler: &'a ProfilingAgent,
    pub(crate) suite: &'a TestSuite,
    pub(crate) base_profile: &'a ProfileReport,
}

/// The run-long mutable state a settling round updates — one borrow
/// bundle so [`settle_round`] can be shared verbatim by both engines.
pub(crate) struct RoundTally<'a> {
    pub(crate) records: &'a mut Vec<RoundRecord>,
    pub(crate) best: &'a mut Kernel,
    pub(crate) best_speedup: &'a mut f64,
    /// Move sequence (from the baseline) of the current global best —
    /// what the store's trajectory record persists at run end.
    pub(crate) best_history: &'a mut Vec<Move>,
    pub(crate) candidates_evaluated: &'a mut usize,
    pub(crate) cancelled_candidates: &'a mut usize,
    pub(crate) fault_stats: &'a mut FaultStats,
    pub(crate) quarantined_lineages: &'a mut u64,
}

/// Everything after a round's evaluations land, shared verbatim by the
/// barriered loop and the pipelined scheduler: the canonical
/// cancellation schedule + repair, canonical fault telemetry, the
/// accept gate + records + global-best update, and next-beam selection.
/// Returns the next beam plus the selection identities (in selection
/// order) — the pipelined scheduler's commit check compares them
/// against the prediction a speculated round was planned from.
#[allow(clippy::too_many_arguments)]
pub(crate) fn settle_round(
    env: &EvalEnv<'_>,
    round: usize,
    round_best: f64,
    beam: Vec<BeamState>,
    cands: &[Candidate],
    per_state: &[StateRound],
    evals: &mut Vec<Option<EvalProduct>>,
    tally: &mut RoundTally<'_>,
) -> (Vec<BeamState>, Vec<SelectedId>) {
    let beam_width = env.cfg.beam_width.max(1);
    let round_budget = env.cfg.round_budget;

    // ---- canonical cancellation schedule + repair ----------------
    // Deterministic reference semantics: walk candidates in index
    // order; once an improver has been seen and `round_budget`
    // candidates have evaluated, every later candidate is abandoned
    // — whatever the race actually did. Kept candidates that the
    // race cancelled are re-run serially (cache-bypassing, like the
    // testing agent's shape repair); completed results of abandoned
    // candidates are discarded. Unreachable at `round_budget = 0`.
    let mut abandoned = vec![false; cands.len()];
    if round_budget > 0 {
        let mut kept = 0usize;
        let mut improver_seen = false;
        for i in 0..cands.len() {
            if improver_seen && kept >= round_budget {
                abandoned[i] = true;
                continue;
            }
            if evals[i].is_none() {
                // The repair re-runs the full supervised evaluation
                // (same candidate key, so injected faults replay
                // identically), under the same panic containment as
                // the racy pass.
                let key = faults::candidate_key(
                    round,
                    cands[i].parent,
                    cands[i].index,
                );
                let repaired =
                    std::panic::catch_unwind(AssertUnwindSafe(|| {
                        evaluate_supervised(
                            env.spec,
                            env.cfg,
                            env.tester,
                            env.profiler,
                            &cands[i].kernel,
                            env.suite,
                            Some(env.base_profile),
                            None,
                            None,
                            None,
                            key,
                        )
                    }));
                evals[i] = Some(match repaired {
                    Ok(product) => product
                        .expect("repair runs without cancellation tokens"),
                    Err(p) => panicked_product(
                        env.profiler,
                        &cands[i].kernel,
                        env.suite,
                        Some(env.base_profile),
                        &panic_message(p),
                    ),
                });
            }
            let product =
                evals[i].as_ref().expect("repaired just above");
            kept += 1;
            if product.tests.pass
                && product.profile.speedup_vs_baseline > round_best
            {
                improver_seen = true;
            }
        }
        let n_abandoned = abandoned.iter().filter(|a| **a).count();
        *tally.cancelled_candidates += n_abandoned;
        *tally.candidates_evaluated += cands.len() - n_abandoned;
    } else {
        *tally.candidates_evaluated += cands.len();
    }

    // ---- canonical fault telemetry (by candidate index) ----------
    // Abandoned candidates contribute nothing: their true stats may
    // not exist (cancelled mid-flight) and must not leak.
    for (i, e) in evals.iter().enumerate() {
        if abandoned[i] {
            continue;
        }
        if let Some(p) = e {
            tally.fault_stats.add(&p.stats);
        }
    }

    // Normalize the eval vector to the canonical outcome: an abandoned
    // candidate's slot is `None` even when the race finished it, so
    // callers can read `Some` == canonically kept (the store's journal
    // writer depends on this).
    for (i, gone) in abandoned.iter().enumerate() {
        if *gone {
            evals[i] = None;
        }
    }

    // ---- gate, record, update the global best (by index) ---------
    let mut gate = vec![false; cands.len()];
    let mut rec_idx = vec![usize::MAX; cands.len()];
    let mut any_accept = vec![false; beam.len()];
    let mut any_pass = vec![false; beam.len()];
    let mut any_kept = vec![false; beam.len()];
    let mut new_blocks: Vec<Vec<Move>> = vec![Vec::new(); beam.len()];
    for (si, sr) in per_state.iter().enumerate() {
        if sr.start == sr.end {
            tally.records.push(RoundRecord {
                round,
                beam_state: si,
                candidate: 0,
                applied: None,
                rationale: String::new(),
                pass: true,
                speedup_internal: round_best,
                mean_us_internal: beam[si].profile.mean_us,
                accepted: false,
                loc: printer::loc(&beam[si].kernel),
                note: if sr.quarantined {
                    format!(
                        "quarantined: lineage disabled after {} \
                         consecutive failed rounds",
                        env.cfg.quarantine_after
                    )
                } else {
                    format!(
                        "no applicable suggestion ({})",
                        sr.reasons.join("; ")
                    )
                },
            });
            continue;
        }
        for ci in sr.start..sr.end {
            let cand = &cands[ci];
            if abandoned[ci] {
                // Canonical cancellation record: constant fields
                // (the candidate's true numbers may not exist and
                // must not leak even when the race finished them).
                tally.records.push(RoundRecord {
                    round,
                    beam_state: si,
                    candidate: cand.index,
                    applied: Some(cand.applied),
                    rationale: cand.rationale.clone(),
                    pass: false,
                    speedup_internal: 0.0,
                    mean_us_internal: 0.0,
                    accepted: false,
                    loc: printer::loc(&cand.kernel),
                    note: "abandoned: a sibling measured strictly \
                           better and the round's speculation budget \
                           was exhausted"
                        .into(),
                });
                continue;
            }
            let product =
                evals[ci].as_ref().expect("kept candidates are evaluated");
            let (tests, profile) = (&product.tests, &product.profile);
            any_kept[si] = true;
            any_pass[si] = any_pass[si] || tests.pass;
            let speedup = profile.speedup_vs_baseline;
            let improved = speedup >= round_best * ACCEPT_THRESHOLD;
            let accepted = tests.pass && improved;
            let note = if !tests.pass {
                match &tests.failure {
                    Some(f) => format!("rejected: runtime failure ({f})"),
                    None => format!(
                        "rejected: numerical mismatch (rel {:.2e})",
                        tests.max_rel_err
                    ),
                }
            } else if !improved {
                new_blocks[si].push(cand.applied);
                format!(
                    "rejected: measured {:.2}x vs best {:.2}x — move blocked",
                    speedup, round_best
                )
            } else {
                format!("accepted at {:.2}x (internal)", speedup)
            };
            gate[ci] = accepted;
            any_accept[si] = any_accept[si] || accepted;
            rec_idx[ci] = tally.records.len();
            tally.records.push(RoundRecord {
                round,
                beam_state: si,
                candidate: cand.index,
                applied: Some(cand.applied),
                rationale: cand.rationale.clone(),
                pass: tests.pass,
                speedup_internal: speedup,
                mean_us_internal: profile.mean_us,
                accepted,
                loc: printer::loc(&cand.kernel),
                note,
            });
            if accepted && speedup > *tally.best_speedup {
                *tally.best = cand.kernel.clone();
                *tally.best_speedup = speedup;
                let mut history = beam[si].history.clone();
                history.push(cand.applied);
                *tally.best_history = history;
            }
        }
    }

    // ---- select the next beam ------------------------------------
    let mut pool: Vec<PoolEntry> = Vec::new();
    for ci in 0..cands.len() {
        if !gate[ci] {
            continue;
        }
        let product =
            evals[ci].as_ref().expect("gated candidates are evaluated");
        pool.push(PoolEntry {
            state: BeamState {
                kernel: cands[ci].kernel.clone(),
                tests: product.tests.clone(),
                profile: product.profile.clone(),
                speedup: product.profile.speedup_vs_baseline,
                history: {
                    let mut h = beam[cands[ci].parent].history.clone();
                    h.push(cands[ci].applied);
                    h
                },
                // Fresh kernel, fresh block set: a move that did not
                // pay on the parent may pay here.
                blocked: Vec::new(),
                // An accepted child passed its tests: fresh lineage.
                consec_failures: 0,
            },
            score: product.profile.speedup_vs_baseline,
            parent: cands[ci].parent,
            cand: cands[ci].index,
            fresh: true,
            rec: rec_idx[ci],
        });
    }
    let n_states = any_accept.len();
    let mut superseded: Vec<(usize, BeamState)> = Vec::new();
    for (si, mut state) in beam.into_iter().enumerate() {
        state.blocked.append(&mut new_blocks[si]);
        // Lineage health: a round where candidates were kept but
        // every kept candidate *failed its tests* counts against the
        // lineage; any passing kept candidate (even a non-improving
        // one) resets it. Rounds with nothing kept (cancelled, no
        // applicable suggestion, already quarantined) leave the
        // counter untouched.
        if any_kept[si] {
            if any_pass[si] {
                state.consec_failures = 0;
            } else {
                state.consec_failures += 1;
                if env.cfg.quarantine_after > 0
                    && state.consec_failures == env.cfg.quarantine_after
                {
                    *tally.quarantined_lineages += 1;
                }
            }
        }
        if any_accept[si] {
            // Replaced by its accepted candidate(s); held back only
            // for the narrow-beam fallback below.
            superseded.push((si, state));
        } else {
            pool.push(PoolEntry {
                score: state.speedup,
                state,
                parent: si,
                cand: usize::MAX,
                fresh: false,
                rec: usize::MAX,
            });
        }
    }
    pool.sort_by(|a, b| {
        b.score
            .total_cmp(&a.score)
            .then_with(|| b.fresh.cmp(&a.fresh))
            .then_with(|| a.parent.cmp(&b.parent))
            .then_with(|| a.cand.cmp(&b.cand))
    });
    let mut selected: Vec<PoolEntry> = Vec::new();
    let mut selection: Vec<SelectedId> = Vec::new();
    let mut child_selected = vec![false; n_states];
    for entry in pool {
        let full = selected.len() >= beam_width;
        let dup = selected
            .iter()
            .any(|s| s.state.kernel == entry.state.kernel);
        if full || dup {
            if entry.fresh && entry.rec != usize::MAX {
                tally.records[entry.rec].accepted = false;
                tally.records[entry.rec].note.push_str(if dup {
                    "; dropped: duplicate beam state"
                } else {
                    "; dropped: beam full"
                });
            }
            continue;
        }
        if entry.fresh {
            child_selected[entry.parent] = true;
        }
        selection.push(SelectedId {
            parent: entry.parent,
            cand: entry.cand,
            fresh: entry.fresh,
        });
        selected.push(entry);
    }
    // Fallback: a parent whose accepted candidates all got deduped
    // or squeezed out would otherwise vanish and silently narrow
    // the beam; re-offer such parents (in index order) while room
    // remains. Unreachable at B = K = 1, where the single accepted
    // child is always selected.
    for (si, state) in superseded {
        if selected.len() >= beam_width {
            break;
        }
        if child_selected[si]
            || selected.iter().any(|s| s.state.kernel == state.kernel)
        {
            continue;
        }
        selection.push(SelectedId {
            parent: si,
            cand: usize::MAX,
            fresh: false,
        });
        selected.push(PoolEntry {
            score: state.speedup,
            state,
            parent: si,
            cand: usize::MAX,
            fresh: false,
            rec: usize::MAX,
        });
    }
    (selected.into_iter().map(|e| e.state).collect(), selection)
}

/// Run the speculative beam search on one kernel (per-run cache).
pub fn optimize_beam(spec: &KernelSpec, cfg: &Config) -> Outcome {
    let cache = CompileCache::with_default_capacity();
    optimize_beam_with_cache(spec, cfg, &cache)
}

/// [`optimize_beam`] against a caller-owned compile cache — the seam the
/// cross-run sharing in `optimize_all_parallel` builds on (it passes a
/// per-run front cache backed by the shared one, so `Outcome` cache
/// counters stay per-run exact; see [`CompileCache::with_backing`]).
/// Compiles are pure, so cache topology never changes a trajectory.
pub fn optimize_beam_with_cache(
    spec: &KernelSpec,
    cfg: &Config,
    cache: &CompileCache,
) -> Outcome {
    let budget = Arc::new(WorkerBudget::from_config(cfg.worker_budget));
    optimize_beam_with_cache_budget(spec, cfg, cache, &budget)
}

/// [`optimize_beam_with_cache`] against a caller-owned *worker budget*
/// as well — the process-wide pool `optimize_all_parallel` shares across
/// its concurrent coordinators so candidates × shapes × grid workers
/// never oversubscribe the machine. Budget capacity only changes
/// scheduling (every merge is by index), never a trajectory —
/// test-pinned in `coordinator/run.rs`.
pub(crate) fn optimize_beam_with_cache_budget(
    spec: &KernelSpec,
    cfg: &Config,
    cache: &CompileCache,
    budget: &Arc<WorkerBudget>,
) -> Outcome {
    if cfg.pipelined && cfg.speculation_depth > 0 && !(cfg.resume && cfg.store_dir.is_some()) {
        // The pipelined engine plans, evaluates and settles through the
        // exact same seams (plan_round / evaluate_supervised /
        // settle_round), so this dispatch changes *scheduling* only —
        // outcomes are differential-pinned byte-identical. With
        // `--pipelined` off or `speculation_depth = 0` the literal
        // legacy loop below runs. `--resume` also runs here: journal
        // replay is a serial prefix, and since the engines are
        // byte-identical a killed pipelined run resumes barriered to
        // the same outcome.
        return super::sched::optimize_pipelined(spec, cfg, cache, budget);
    }
    let quality = match cfg.mode {
        AgentMode::Multi => TestQuality::Representative,
        AgentMode::Single => TestQuality::Unrepresentative,
    };
    let tester = TestingAgent::new(quality, cfg.seed)
        .with_grid_workers(cfg.grid_workers)
        .with_worker_budget(Arc::clone(budget))
        .with_step_limit(cfg.watchdog_steps);
    let profiler = ProfilingAgent::new(cfg.model.clone());
    let mut planner = make_planner(cfg);
    let coder = CodingAgent::new(cfg.bug_rate, cfg.seed ^ 0xC0DE);
    let probe = ConcurrencyProbe::new();

    // ---- artifact store + journal (ROADMAP "crash-consistent store") -
    // Attaching the store to the compile cache persists compile
    // metadata on every miss; the journal replays a killed run's
    // settled rounds; eval-skip reuses recorded validation verdicts.
    // Eval records are only trusted when validation outcomes are
    // fault-independent: no per-round cancellation races (budget 0) and
    // no live injection at non-store sites (store faults hit only the
    // store's own writes, which are checksummed and recomputed cold).
    let store = open_store(cfg);
    if let Some(s) = &store {
        cache.attach_store(Arc::clone(s));
    }
    let runkey = run_key(spec, cfg);
    let eval_skip = store.is_some()
        && cfg.round_budget == 0
        && (!cfg.fault.enabled() || cfg.fault.sites & !FaultSite::Store.bit() == 0);
    let journal_rounds: Vec<crate::store::JournalRound> = match &store {
        Some(s) if cfg.resume => s.read_rounds(runkey),
        Some(s) => {
            s.reset_journal(runkey);
            Vec::new()
        }
        None => Vec::new(),
    };
    let mut next_replay = 0usize;
    let mut replay_ok = cfg.resume;
    let mut resumed_rounds = 0u64;
    let mut killed = false;
    let mut best_history: Vec<Move> = Vec::new();

    // Algorithm 1, lines 1-7: suite + baseline profile, now seeding the
    // one-element beam.
    let baseline = (spec.build_baseline)();
    let suite = tester.generate_tests(spec);
    let base_tests = tester.validate_with(spec, &baseline, &suite, Some(cache));
    let base_profile = profiler.profile(&baseline, &suite, None);
    debug_assert!(base_tests.pass, "baseline must pass its own tests");

    let mut records: Vec<RoundRecord> = Vec::new();
    let mut best = baseline.clone();
    let mut best_speedup = 1.0f64;
    let mut candidates_evaluated = 0usize;
    let mut k_per_round: Vec<usize> = Vec::new();
    let mut adaptive_k_events = 0usize;
    let mut cancelled_candidates = 0usize;
    let mut fault_stats = FaultStats::default();
    let mut quarantined_lineages = 0u64;
    let mut beam: Vec<BeamState> = vec![BeamState {
        kernel: baseline.clone(),
        tests: base_tests,
        profile: base_profile.clone(),
        speedup: 1.0,
        history: Vec::new(),
        blocked: Vec::new(),
        consec_failures: 0,
    }];

    for round in 1..=cfg.rounds {
        // ---- plan + materialize (serial; see module docs) ------------
        let (cands, per_state) = plan_round(
            cfg,
            round,
            &beam,
            planner.as_mut(),
            &coder,
            &mut fault_stats,
            &mut k_per_round,
            &mut adaptive_k_events,
        );

        // ---- evaluate all candidates concurrently --------------------
        // The candidates form a work queue drained by `1 + granted`
        // scoped workers (the coordinator thread is the first; extra
        // workers need tokens from the process-wide budget, so beam
        // speculation degrades to serial evaluation rather than
        // oversubscribing shape- and grid-level workers). Each eval's
        // validate fans out further per shape. Results land by candidate
        // index, so the merge below is order-independent.
        //
        // Beam-round cancellation (`round_budget > 0`, ROADMAP
        // "beam-state-level cancellation"): a per-round token layered
        // over each candidate's validation token abandons in-flight
        // sibling validations once `round_budget` candidates have fully
        // evaluated and one measured strictly better than the global
        // best at round start — the Block-STM pattern of dropping work
        // the moment a result proves it moot. Which candidates a *race*
        // cancels is timing-dependent, so the canonical repair pass
        // below re-derives the abandonment set deterministically (in
        // candidate index order, from true results only) and re-runs
        // any racily-cancelled candidate the canonical schedule keeps:
        // outcomes are byte-identical at every worker count and budget
        // capacity. Cancellable evals bypass the compile cache — how
        // far a cancelled validation got is a race, and its lookups
        // would make the run's hit/miss counters nondeterministic (the
        // testing agent's shape-repair trade, one level up).
        let round_best = best_speedup;
        let round_budget = cfg.round_budget;
        // Per-candidate compile-cache probe logs, recorded so eval
        // records and journal frames can replay exact cache traffic on
        // warm-start and resume.
        let probe_logs: Option<Vec<Mutex<Vec<u64>>>> =
            if store.is_some() && round_budget == 0 {
                Some((0..cands.len()).map(|_| Mutex::new(Vec::new())).collect())
            } else {
                None
            };
        // ---- journal replay: the settled prefix of a resumed run -----
        // A frame replays only if it matches this round exactly (same
        // round number, same candidate count — the serial planner
        // guarantees the candidates themselves match); the first
        // mismatch permanently ends replay and the run continues live.
        let replay_slots: Option<Vec<Option<EvalSlot>>> = if replay_ok {
            match journal_rounds.get(next_replay) {
                Some(jr) if jr.round == round && jr.slots.len() == cands.len() => {
                    next_replay += 1;
                    Some(jr.slots.clone())
                }
                _ => {
                    replay_ok = false;
                    None
                }
            }
        } else {
            None
        };
        let was_replayed = replay_slots.is_some();
        let mut evals: Vec<Option<EvalProduct>> = if let Some(slots) = replay_slots {
            // Recorded verdicts and fault stats stand in for the
            // evaluations this process never ran. Cache probes are
            // replayed per recorded attempt key so the compile cache's
            // hit/miss ledger matches the uninterrupted run exactly;
            // profiles are pure functions of the kernel and recompute
            // for free. `None` slots were canonically abandoned.
            resumed_rounds += 1;
            slots
                .into_iter()
                .enumerate()
                .map(|(i, slot)| {
                    let EvalSlot { tests, stats, probe_keys } = slot?;
                    replay_probes(&tester, cfg, &cands[i].kernel, &suite, cache, &probe_keys);
                    if let Some(logs) = &probe_logs {
                        *logs[i].lock().unwrap() = probe_keys;
                    }
                    Some(EvalProduct {
                        tests,
                        profile: profiler.profile(&cands[i].kernel, &suite, Some(&base_profile)),
                        stats,
                    })
                })
                .collect()
        } else {
            // Recorded-eval preload runs serially in candidate-index
            // order, so store hit/miss counters are a pure function of
            // disk state rather than eval scheduling. Same-round
            // duplicate kernels both miss here and evaluate live; the
            // next round sees the settled record.
            let preloaded: Vec<Option<EvalSlot>> = match &store {
                Some(s) if eval_skip => cands
                    .iter()
                    .map(|c| s.load_eval(eval_record_key(spec, cfg, kernel_hash(&c.kernel))))
                    .collect(),
                _ => vec![None; cands.len()],
            };
            let round_cancel = AtomicBool::new(false);
            let cand_tokens: Vec<AtomicBool> =
                (0..cands.len()).map(|_| AtomicBool::new(false)).collect();
            let evals_done = AtomicUsize::new(0);
            let improver_racy = AtomicBool::new(false);
            // `run_indexed_catching` is the panic-containment boundary: a
            // candidate whose worker panics (injected or not) lands as
            // `Err(message)` in its own slot and is converted below into a
            // canonical failed record instead of crashing the round.
            let raw = run_indexed_catching(Some(budget.as_ref()), cands.len(), |i| {
                let cand = &cands[i];
                let _in_flight = probe.enter();
                let key = faults::candidate_key(round, cand.parent, cand.index);
                if round_budget == 0 {
                    if let Some(slot) = &preloaded[i] {
                        // Warm start: the recorded verdict stands in
                        // for validation; replaying its probes keeps
                        // cache counters identical to a cold run.
                        replay_probes(&tester, cfg, &cand.kernel, &suite, cache, &slot.probe_keys);
                        if let Some(logs) = &probe_logs {
                            *logs[i].lock().unwrap() = slot.probe_keys.clone();
                        }
                        return Some(EvalProduct {
                            tests: slot.tests.clone(),
                            profile: profiler.profile(&cand.kernel, &suite, Some(&base_profile)),
                            stats: slot.stats,
                        });
                    }
                    let product = evaluate_supervised(
                        spec,
                        cfg,
                        &tester,
                        &profiler,
                        &cand.kernel,
                        &suite,
                        Some(&base_profile),
                        Some(cache),
                        None,
                        probe_logs.as_ref().map(|l| &l[i]),
                        key,
                    )?;
                    if eval_skip {
                        if let Some(s) = &store {
                            let probe_keys = probe_logs
                                .as_ref()
                                .map(|l| l[i].lock().unwrap().clone())
                                .unwrap_or_default();
                            s.save_eval(
                                eval_record_key(spec, cfg, kernel_hash(&cand.kernel)),
                                &EvalSlot {
                                    tests: product.tests.clone(),
                                    stats: product.stats,
                                    probe_keys,
                                },
                            );
                        }
                    }
                    return Some(product);
                }
                let product = evaluate_supervised(
                    spec,
                    cfg,
                    &tester,
                    &profiler,
                    &cand.kernel,
                    &suite,
                    Some(&base_profile),
                    None,
                    Some((&cand_tokens[i], &round_cancel)),
                    None,
                    key,
                )?;
                let done = evals_done.fetch_add(1, Ordering::SeqCst) + 1;
                if product.tests.pass
                    && product.profile.speedup_vs_baseline > round_best
                {
                    improver_racy.store(true, Ordering::SeqCst);
                }
                if improver_racy.load(Ordering::SeqCst) && done >= round_budget {
                    // Raise the round token first, then every candidate
                    // token: a machine that observes its candidate token
                    // can then rely on the round flag being visible.
                    round_cancel.store(true, Ordering::SeqCst);
                    for t in &cand_tokens {
                        t.store(true, Ordering::SeqCst);
                    }
                }
                Some(product)
            });
            raw.into_iter()
                .enumerate()
                .map(|(i, r)| match r {
                    Ok(v) => v,
                    Err(msg) => Some(panicked_product(
                        &profiler,
                        &cands[i].kernel,
                        &suite,
                        Some(&base_profile),
                        &msg,
                    )),
                })
                .collect()
        };

        // ---- settle: canonical repair, gate + record, selection ------
        let env = EvalEnv {
            spec,
            cfg,
            tester: &tester,
            profiler: &profiler,
            suite: &suite,
            base_profile: &base_profile,
        };
        let mut tally = RoundTally {
            records: &mut records,
            best: &mut best,
            best_speedup: &mut best_speedup,
            best_history: &mut best_history,
            candidates_evaluated: &mut candidates_evaluated,
            cancelled_candidates: &mut cancelled_candidates,
            fault_stats: &mut fault_stats,
            quarantined_lineages: &mut quarantined_lineages,
        };
        let (next_beam, _selection) = settle_round(
            &env,
            round,
            round_best,
            beam,
            &cands,
            &per_state,
            &mut evals,
            &mut tally,
        );
        beam = next_beam;

        // ---- journal checkpoint (live rounds only; replayed rounds
        // are already on disk). `settle_round` has normalized `evals`
        // so `Some` means canonically kept — a resume replays exactly
        // the abandonment this round settled on. The hidden kill knob
        // crashes the run right after the checkpoint, which is what
        // the kill-and-resume walls exercise.
        if let Some(s) = &store {
            if !was_replayed {
                let slots: Vec<Option<EvalSlot>> = evals
                    .iter()
                    .enumerate()
                    .map(|(i, e)| {
                        e.as_ref().map(|p| EvalSlot {
                            tests: p.tests.clone(),
                            stats: p.stats,
                            probe_keys: probe_logs
                                .as_ref()
                                .map(|l| l[i].lock().unwrap().clone())
                                .unwrap_or_default(),
                        })
                    })
                    .collect();
                s.append_round(runkey, round, &slots);
            }
            if cfg.kill_after_round > 0 && round == cfg.kill_after_round {
                killed = true;
                break;
            }
        }
    }

    // ---- warm start: replay the stored best trajectory ---------------
    // Skipped when the hidden kill knob crashed the run mid-search —
    // a real crash never reaches run end either.
    if let Some(s) = &store {
        if !killed {
            warm_finish(
                s,
                spec,
                cfg,
                &tester,
                &profiler,
                cache,
                &suite,
                &baseline,
                &base_profile,
                &mut records,
                &mut best,
                &mut best_speedup,
                &mut best_history,
            );
        }
    }
    let store_ledger = harvest_store(&store, resumed_rounds);

    finish_outcome(
        spec,
        cfg,
        records,
        baseline,
        best,
        cache,
        budget,
        SearchTelemetry {
            candidates_evaluated,
            peak_concurrent_evals: probe.peak(),
            k_per_round,
            adaptive_k_rounds: adaptive_k_events,
            cancelled_candidates,
            fault_stats,
            quarantined_lineages,
            speculation: SpecLedger::default(),
            store: store_ledger,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{optimize, optimize_greedy};
    use crate::kernels;
    use std::thread;

    fn sugg(priority: f64) -> Suggestion {
        Suggestion {
            mv: crate::transforms::Move::Hoist,
            rationale: String::new(),
            priority,
        }
    }

    #[test]
    fn adaptive_k_interpolates_between_floor_and_ceiling() {
        let mut cfg = Config {
            candidates_per_round: 5,
            adaptive_candidates: true,
            adaptive_min_candidates: 1,
            adaptive_gap_threshold: 0.5,
            ..Config::multi_agent()
        };
        // Tied ranking: full ceiling.
        assert_eq!(adaptive_k(&cfg, &[sugg(3.0), sugg(3.0), sugg(3.0)]), 5);
        // Dominant (gap >= threshold): the floor.
        assert_eq!(adaptive_k(&cfg, &[sugg(9.0), sugg(1.0), sugg(1.0)]), 1);
        // Single suggestion: nothing to speculate on.
        assert_eq!(adaptive_k(&cfg, &[sugg(9.0)]), 1);
        // Halfway to the threshold: halfway down the K range.
        // gap = (9-7)/(9-1) = 0.25, frac = 0.5 -> K = 5 - 0.5*4 = 3.
        assert_eq!(adaptive_k(&cfg, &[sugg(9.0), sugg(7.0), sugg(1.0)]), 3);
        // Floor clamps into [1, ceiling].
        cfg.adaptive_min_candidates = 3;
        assert_eq!(adaptive_k(&cfg, &[sugg(9.0), sugg(1.0)]), 3);
        cfg.adaptive_min_candidates = 99;
        assert_eq!(adaptive_k(&cfg, &[sugg(9.0), sugg(1.0)]), 5);
    }

    #[test]
    fn adaptive_k_is_static_when_off_or_threshold_zero() {
        let dominant = [sugg(9.0), sugg(1.0)];
        let off = Config {
            candidates_per_round: 4,
            ..Config::multi_agent()
        };
        assert_eq!(adaptive_k(&off, &dominant), 4);
        let zero = Config {
            candidates_per_round: 4,
            adaptive_candidates: true,
            adaptive_gap_threshold: 0.0,
            ..Config::multi_agent()
        };
        assert_eq!(adaptive_k(&zero, &dominant), 4, "threshold 0 = static");
        assert_eq!(adaptive_k(&zero, &[]), 4);
    }

    #[test]
    fn finish_outcome_post_processing_respects_a_serial_worker_budget() {
        // The peak-live witness for the budgeted tail (ROADMAP
        // "budgeted post-processing"): with a budget of 1 and the test
        // thread pre-counted as the one live worker, every
        // post-processing task — oracle re-validation AND both profile
        // sweeps — must execute on this thread; any unbudgeted spawn
        // that touches budgeted work would push `peak_live` to 2.
        let spec = kernels::silu::spec();
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent()
        };
        let baseline = (spec.build_baseline)();
        let cache = CompileCache::with_default_capacity();
        let budget = Arc::new(WorkerBudget::new(1));
        let caller = budget.count_worker();
        let out = finish_outcome(
            &spec,
            &cfg,
            Vec::new(),
            baseline.clone(),
            baseline,
            &cache,
            &budget,
            SearchTelemetry {
                candidates_evaluated: 0,
                peak_concurrent_evals: 0,
                k_per_round: Vec::new(),
                adaptive_k_rounds: 0,
                cancelled_candidates: 0,
                fault_stats: FaultStats::default(),
                quarantined_lineages: 0,
                speculation: SpecLedger::default(),
                store: StoreLedger::default(),
            },
        );
        drop(caller);
        assert!(out.final_correct);
        assert!(
            (out.final_speedup - 1.0).abs() < 1e-12,
            "baseline vs baseline is 1.0x, got {}",
            out.final_speedup
        );
        assert_eq!(
            budget.peak_live(),
            1,
            "post-processing must stay on the calling thread when the \
             budget is serial (no unbudgeted spawns)"
        );
    }

    #[test]
    fn adaptive_scheduler_spends_less_speculation_than_static() {
        // A tiny gap threshold makes any strictly-dominant top
        // suggestion shrink K to the floor, so the adaptive run must
        // evaluate fewer candidates than the static B x K grid on the
        // same seed — while still shipping a correct kernel at the
        // greedy trajectory's speedup or better.
        let spec = kernels::merge::spec();
        let static_cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent_beam()
        };
        let adaptive_cfg = Config {
            adaptive_candidates: true,
            adaptive_min_candidates: 1,
            adaptive_gap_threshold: 0.01,
            ..static_cfg.clone()
        };
        let s = optimize_beam(&spec, &static_cfg);
        let a = optimize_beam(&spec, &adaptive_cfg);
        assert!(a.final_correct);
        assert!(
            a.candidates_evaluated < s.candidates_evaluated,
            "adaptive {} vs static {}",
            a.candidates_evaluated,
            s.candidates_evaluated
        );
        assert!(a.adaptive_k_rounds > 0, "the scheduler never shrank K");
        assert_eq!(
            a.k_per_round.iter().filter(|k| **k < 3).count(),
            a.adaptive_k_rounds,
            "telemetry consistency"
        );
        assert_eq!(s.adaptive_k_rounds, 0);
        assert!(s.k_per_round.iter().all(|k| *k == 3));
    }

    #[test]
    fn round_cancellation_fires_and_is_deterministic() {
        // B=1, K=3, round budget 1: canonically, the first candidate of
        // round 1 (hoist on merge — accepted at >1x) is an improver, so
        // both siblings of every improving round are abandoned. The
        // outcome — records, kernels, telemetry — must not depend on
        // worker budget or repetition.
        let spec = kernels::merge::spec();
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            beam_width: 1,
            candidates_per_round: 3,
            round_budget: 1,
            ..Config::multi_agent()
        };
        let a = optimize_beam(&spec, &cfg);
        assert!(a.final_correct);
        assert!(
            a.cancelled_candidates > 0,
            "round budget 1 must abandon sibling candidates"
        );
        assert!(a
            .records
            .iter()
            .any(|r| r.note.starts_with("abandoned:")));
        // Abandoned records are inert: never accepted, never passing.
        for r in a.records.iter().filter(|r| r.note.starts_with("abandoned:")) {
            assert!(!r.accepted);
            assert!(!r.pass);
            assert_eq!(r.speedup_internal, 0.0);
        }
        for budget_knob in [1usize, 2, 0] {
            let budget = Arc::new(WorkerBudget::from_config(budget_knob));
            let b = crate::coordinator::optimize_with_budget(&spec, &cfg, &budget);
            assert_eq!(a.records, b.records, "budget {budget_knob}");
            assert_eq!(a.best, b.best, "budget {budget_knob}");
            assert_eq!(
                a.cancelled_candidates, b.cancelled_candidates,
                "budget {budget_knob}"
            );
            assert_eq!(a.candidates_evaluated, b.candidates_evaluated);
            assert_eq!(a.k_per_round, b.k_per_round);
            assert_eq!(a.cache_hits, b.cache_hits, "budget {budget_knob}");
            assert_eq!(a.cache_misses, b.cache_misses, "budget {budget_knob}");
            assert_eq!(
                a.final_speedup.to_bits(),
                b.final_speedup.to_bits(),
                "budget {budget_knob}"
            );
        }
    }

    #[test]
    fn beam_matches_or_beats_greedy_on_every_kernel_default_config() {
        // The acceptance bar: the default beam configuration must never
        // ship a slower kernel than the greedy loop it generalizes, on
        // the same seed.
        for spec in kernels::all_specs() {
            let greedy_cfg = Config::multi_agent();
            let beam_cfg = Config::multi_agent_beam();
            let g = optimize(&spec, &greedy_cfg);
            let b = optimize(&spec, &beam_cfg);
            assert!(b.final_correct, "{}", spec.paper_name);
            assert!(
                b.final_speedup >= g.final_speedup * (1.0 - 1e-9),
                "{}: beam {:.3}x < greedy {:.3}x",
                spec.paper_name,
                b.final_speedup,
                g.final_speedup
            );
            assert!(
                b.candidates_evaluated > g.candidates_evaluated,
                "beam must speculate more than greedy"
            );
            // Concurrency witness: with >= 2 workers available, candidate
            // evaluations must have overlapped in flight.
            let cores = thread::available_parallelism().map_or(1, |n| n.get());
            if cores >= 2 {
                assert!(
                    b.peak_concurrent_evals >= 2,
                    "{}: candidate evaluations never overlapped (peak {})",
                    spec.paper_name,
                    b.peak_concurrent_evals
                );
            }
        }
    }

    #[test]
    fn beam_is_deterministic_despite_parallel_evaluation() {
        let cfg = Config {
            seed: 7,
            ..Config::multi_agent_beam()
        };
        let spec = kernels::merge::spec();
        let a = optimize_beam(&spec, &cfg);
        let b = optimize_beam(&spec, &cfg);
        assert_eq!(a.records, b.records);
        assert_eq!(a.best, b.best);
        assert_eq!(a.final_speedup.to_bits(), b.final_speedup.to_bits());
        assert_eq!(a.candidates_evaluated, b.candidates_evaluated);
    }

    #[test]
    fn beam_records_carry_state_and_candidate_indices() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent_beam()
        };
        let out = optimize_beam(&kernels::merge::spec(), &cfg);
        assert!(out.records.len() > cfg.rounds, "speculation widens the log");
        // Round numbers are non-decreasing and candidate indices are
        // within the configured width.
        let mut last_round = 0;
        for r in &out.records {
            assert!(r.round >= last_round);
            last_round = r.round;
            assert!(r.beam_state < cfg.beam_width);
            assert!(r.candidate < cfg.candidates_per_round);
        }
        // The first round speculates from a single state.
        assert!(out
            .records
            .iter()
            .filter(|r| r.round == 1)
            .all(|r| r.beam_state == 0));
        // Compile caching must have kicked in (duplicate candidates or
        // the final oracle pass re-validating the winner).
        assert!(out.cache_hits > 0, "cache never hit: {:?}", out.cache_hits);
    }

    #[test]
    fn wider_beam_cannot_regress_final_speedup_quiet() {
        // Quiet (deterministic) setting: widening the search may only
        // help or tie on the kernels' small move space.
        for spec in kernels::all_specs() {
            let quiet = Config {
                bug_rate: 0.0,
                temperature: 0.0,
                ..Config::multi_agent()
            };
            let wide = Config {
                beam_width: 2,
                candidates_per_round: 3,
                ..quiet.clone()
            };
            let g = optimize_beam(&spec, &quiet);
            let b = optimize_beam(&spec, &wide);
            assert!(
                b.final_speedup >= g.final_speedup * (1.0 - 1e-9),
                "{}: wide {:.3}x < greedy {:.3}x",
                spec.paper_name,
                b.final_speedup,
                g.final_speedup
            );
        }
    }

    #[test]
    fn candidate_streams_are_pairwise_distinct() {
        let mut seen = Vec::new();
        for round in 1..=3usize {
            for state in 0..3usize {
                for cand in 0..3usize {
                    let mut s = candidate_stream(42, round, state, cand);
                    seen.push(s.next_u64());
                }
            }
        }
        let mut dedup = seen.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seen.len(), "stream collision");
    }

    #[test]
    fn greedy_oracle_probe_and_cache_fields_populate() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent()
        };
        let out = optimize_greedy(&kernels::silu::spec(), &cfg);
        assert!(out.candidates_evaluated >= 1);
        assert_eq!(out.peak_concurrent_evals, 1, "greedy evaluates serially");
        assert!(out.cache_misses > 0);
    }
}
