//! Speculative beam search over planner suggestions — the widened form
//! of Algorithm 1 (ROADMAP "candidate-level parallel rounds").
//!
//! The paper's loop is greedy: one suggestion applied, tested and
//! profiled per round. With validation cheap and thread-safe (PR 1),
//! the coordinator can afford to *speculate*: each round, every beam
//! state hands its top-K planner suggestions to the coding agent, all
//! materialized candidates validate + profile concurrently on scoped
//! workers, and the best `beam_width` states survive into the next
//! round. Related systems (STARK, CUDA Agent in PAPERS.md) report the
//! same widening as the main scaling lever for agentic kernel search.
//!
//! Determinism contract — the paper-fidelity tests depend on it:
//!
//! * planning and candidate materialization stay **serial** (the planner
//!   is a stateful policy; its stream must not depend on thread timing);
//! * each candidate's fumble roll comes from a **derived per-candidate
//!   PRNG stream** ([`candidate_stream`]) keyed by (round, state,
//!   candidate), never from a shared sequential stream;
//! * evaluation results merge **by candidate index**, and next-beam
//!   selection is a deterministic sort (score, then freshness, then
//!   parent/candidate index) with kernel-equality dedup;
//! * at `beam_width = 1, candidates_per_round = 1` the engine reproduces
//!   the greedy trajectory **bit-for-bit**
//!   ([`super::run::optimize_greedy`] is kept as the differential
//!   oracle, the way `interp::reference` backs the compiled machine).
//!
//! Acceptance mirrors the greedy gate per candidate (pass + no geomean
//! regression beyond [`ACCEPT_THRESHOLD`] vs the global best at round
//! start). A state that accepts a candidate is *replaced* by it (the
//! greedy sideways-move semantics); a state whose candidates all fail
//! survives with its per-state blocked-move set grown by this round's
//! non-improving moves. Blocked sets are **per state** and reset when a
//! candidate is accepted: the kernel changed, so a previously
//! non-improving move may pay again (the greedy loop kept stale blocks
//! forever — a bug this module fixes for both engines).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;

use crate::agents::{
    CodingAgent, MockLlm, PlannerPolicy, ProfileReport, ProfilingAgent,
    SingleAgentPlanner, TestQuality, TestReport, TestingAgent,
};
use crate::interp::budget::run_indexed;
use crate::interp::{CompileCache, WorkerBudget};
use crate::ir::{printer, Kernel};
use crate::kernels::KernelSpec;
use crate::sim;
use crate::transforms::Move;
use crate::util::Prng;

use super::run::{
    AgentMode, Config, Outcome, RoundRecord, ACCEPT_THRESHOLD,
};

/// One live beam state: a known-good kernel plus the signals the planner
/// reads and the moves measured non-improving *for this kernel*.
struct BeamState {
    kernel: Kernel,
    tests: TestReport,
    profile: ProfileReport,
    /// Internal geomean speedup vs the round-0 baseline.
    speedup: f64,
    blocked: Vec<Move>,
}

/// One materialized candidate awaiting evaluation.
struct Candidate {
    /// Beam state (parent) index.
    parent: usize,
    /// Candidate index within the parent (0 = the greedy choice).
    index: usize,
    kernel: Kernel,
    applied: Move,
    rationale: String,
}

/// Per-state materialization summary for one round.
struct StateRound {
    /// Range into the round's candidate vector.
    start: usize,
    end: usize,
    /// Inapplicability reasons (reported when nothing materialized).
    reasons: Vec<String>,
}

/// A next-beam contender: an accepted candidate (fresh) or a surviving
/// parent.
struct PoolEntry {
    state: BeamState,
    score: f64,
    parent: usize,
    cand: usize,
    fresh: bool,
    /// Index of the candidate's `RoundRecord` (patched if selection
    /// drops it), `usize::MAX` for surviving parents.
    rec: usize,
}

/// Run telemetry carried into the [`Outcome`].
pub(crate) struct SearchTelemetry {
    pub(crate) candidates_evaluated: usize,
    pub(crate) peak_concurrent_evals: usize,
}

/// Counts in-flight candidate evaluations and remembers the peak — the
/// concurrency witness the beam tests read from the outcome.
#[derive(Default)]
pub(crate) struct ConcurrencyProbe {
    cur: AtomicUsize,
    peak: AtomicUsize,
}

impl ConcurrencyProbe {
    pub(crate) fn new() -> ConcurrencyProbe {
        ConcurrencyProbe {
            cur: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    pub(crate) fn enter(&self) -> ProbeGuard<'_> {
        let n = self.cur.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(n, Ordering::SeqCst);
        ProbeGuard { probe: self }
    }

    pub(crate) fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }
}

pub(crate) struct ProbeGuard<'a> {
    probe: &'a ConcurrencyProbe,
}

impl Drop for ProbeGuard<'_> {
    fn drop(&mut self) {
        self.probe.cur.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Derived PRNG stream for one speculative edit, stable in
/// (round, state, candidate) — independent of how many siblings
/// materialized before it, and shared verbatim with the greedy oracle
/// (which is always `(round, 0, 0)`).
pub(crate) fn candidate_stream(
    seed: u64,
    round: usize,
    state: usize,
    cand: usize,
) -> Prng {
    let tag = ((round as u64) << 32) ^ ((state as u64) << 16) ^ cand as u64;
    Prng::seed((seed ^ 0xC0DE).wrapping_add(tag.wrapping_mul(0x9E3779B97F4A7C15)))
}

/// Mode-appropriate planner policy (the LLM seam).
pub(crate) fn make_planner(cfg: &Config) -> Box<dyn PlannerPolicy> {
    match cfg.mode {
        AgentMode::Multi => Box::new(MockLlm::new(cfg.temperature, cfg.seed)),
        AgentMode::Single => {
            Box::new(SingleAgentPlanner::new(cfg.temperature, cfg.seed))
        }
    }
}

/// Post-processing shared by both engines (§3.2): oracle re-validation
/// and representative-shape measurement on concurrent scoped workers,
/// then outcome assembly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_outcome(
    spec: &KernelSpec,
    cfg: &Config,
    records: Vec<RoundRecord>,
    baseline: Kernel,
    best: Kernel,
    cache: &CompileCache,
    budget: &Arc<WorkerBudget>,
    telemetry: SearchTelemetry,
) -> Outcome {
    let shapes = (spec.representative_shapes)();
    let (final_correct, base_reports, best_reports) = thread::scope(|s| {
        let correct = s.spawn(|| {
            let final_tester =
                TestingAgent::new(TestQuality::Representative, cfg.seed ^ 0xFEED)
                    .with_grid_workers(cfg.grid_workers)
                    .with_worker_budget(Arc::clone(budget));
            let final_suite = final_tester.generate_tests(spec);
            final_tester
                .validate_with(spec, &best, &final_suite, Some(cache))
                .pass
        });
        let base = s.spawn(|| sim::profile_shapes(&cfg.model, &baseline, &shapes));
        let opt = s.spawn(|| sim::profile_shapes(&cfg.model, &best, &shapes));
        (
            correct.join().expect("oracle re-validation worker panicked"),
            base.join().expect("baseline profile worker panicked"),
            opt.join().expect("optimized profile worker panicked"),
        )
    });
    let per_shape: Vec<(String, f64, f64, f64)> = shapes
        .iter()
        .zip(base_reports.iter().zip(&best_reports))
        .map(|(d, (b, o))| {
            (
                spec.shape_label(d),
                b.total_us,
                o.total_us,
                b.total_us / o.total_us,
            )
        })
        .collect();
    let final_speedup = sim::geomean_speedup(&base_reports, &best_reports);
    let base_mean_us =
        base_reports.iter().map(|r| r.total_us).sum::<f64>() / shapes.len() as f64;
    let opt_mean_us =
        best_reports.iter().map(|r| r.total_us).sum::<f64>() / shapes.len() as f64;
    let cache_stats = cache.stats();

    Outcome {
        kernel_name: spec.paper_name.to_string(),
        mode: cfg.mode,
        records,
        baseline_loc: printer::loc(&baseline),
        best_loc: printer::loc(&best),
        baseline,
        best,
        final_speedup,
        per_shape,
        final_correct,
        base_mean_us,
        opt_mean_us,
        candidates_evaluated: telemetry.candidates_evaluated,
        peak_concurrent_evals: telemetry.peak_concurrent_evals,
        cache_hits: cache_stats.hits,
        cache_misses: cache_stats.misses,
    }
}

/// Run the speculative beam search on one kernel (per-run cache).
pub fn optimize_beam(spec: &KernelSpec, cfg: &Config) -> Outcome {
    let cache = CompileCache::with_default_capacity();
    optimize_beam_with_cache(spec, cfg, &cache)
}

/// [`optimize_beam`] against a caller-owned compile cache — the seam the
/// cross-run sharing in `optimize_all_parallel` builds on (it passes a
/// per-run front cache backed by the shared one, so `Outcome` cache
/// counters stay per-run exact; see [`CompileCache::with_backing`]).
/// Compiles are pure, so cache topology never changes a trajectory.
pub fn optimize_beam_with_cache(
    spec: &KernelSpec,
    cfg: &Config,
    cache: &CompileCache,
) -> Outcome {
    let budget = Arc::new(WorkerBudget::from_config(cfg.worker_budget));
    optimize_beam_with_cache_budget(spec, cfg, cache, &budget)
}

/// [`optimize_beam_with_cache`] against a caller-owned *worker budget*
/// as well — the process-wide pool `optimize_all_parallel` shares across
/// its concurrent coordinators so candidates × shapes × grid workers
/// never oversubscribe the machine. Budget capacity only changes
/// scheduling (every merge is by index), never a trajectory —
/// test-pinned in `coordinator/run.rs`.
pub(crate) fn optimize_beam_with_cache_budget(
    spec: &KernelSpec,
    cfg: &Config,
    cache: &CompileCache,
    budget: &Arc<WorkerBudget>,
) -> Outcome {
    let beam_width = cfg.beam_width.max(1);
    let k_per_state = cfg.candidates_per_round.max(1);
    let quality = match cfg.mode {
        AgentMode::Multi => TestQuality::Representative,
        AgentMode::Single => TestQuality::Unrepresentative,
    };
    let tester = TestingAgent::new(quality, cfg.seed)
        .with_grid_workers(cfg.grid_workers)
        .with_worker_budget(Arc::clone(budget));
    let profiler = ProfilingAgent::new(cfg.model.clone());
    let mut planner = make_planner(cfg);
    let coder = CodingAgent::new(cfg.bug_rate, cfg.seed ^ 0xC0DE);
    let probe = ConcurrencyProbe::new();

    // Algorithm 1, lines 1-7: suite + baseline profile, now seeding the
    // one-element beam.
    let baseline = (spec.build_baseline)();
    let suite = tester.generate_tests(spec);
    let base_tests = tester.validate_with(spec, &baseline, &suite, Some(cache));
    let base_profile = profiler.profile(&baseline, &suite, None);
    debug_assert!(base_tests.pass, "baseline must pass its own tests");

    let mut records: Vec<RoundRecord> = Vec::new();
    let mut best = baseline.clone();
    let mut best_speedup = 1.0f64;
    let mut candidates_evaluated = 0usize;
    let mut beam: Vec<BeamState> = vec![BeamState {
        kernel: baseline.clone(),
        tests: base_tests,
        profile: base_profile.clone(),
        speedup: 1.0,
        blocked: Vec::new(),
    }];

    for round in 1..=cfg.rounds {
        // ---- plan + materialize (serial; see module docs) ------------
        let mut cands: Vec<Candidate> = Vec::new();
        let mut per_state: Vec<StateRound> = Vec::with_capacity(beam.len());
        for (si, state) in beam.iter().enumerate() {
            let mut suggestions =
                planner.suggest(&state.kernel, &state.tests, &state.profile);
            suggestions.retain(|s| !state.blocked.contains(&s.mv));
            let start = cands.len();
            let mut reasons = Vec::new();
            for s in &suggestions {
                let ci = cands.len() - start;
                if ci >= k_per_state {
                    break;
                }
                let mut stream = candidate_stream(cfg.seed, round, si, ci);
                match coder.apply_one(&state.kernel, s, &mut stream) {
                    Ok(kernel) => cands.push(Candidate {
                        parent: si,
                        index: ci,
                        kernel,
                        applied: s.mv,
                        rationale: s.rationale.clone(),
                    }),
                    Err(e) => reasons.push(e),
                }
            }
            per_state.push(StateRound {
                start,
                end: cands.len(),
                reasons,
            });
        }

        // ---- evaluate all candidates concurrently --------------------
        // The candidates form a work queue drained by `1 + granted`
        // scoped workers (the coordinator thread is the first; extra
        // workers need tokens from the process-wide budget, so beam
        // speculation degrades to serial evaluation rather than
        // oversubscribing shape- and grid-level workers). Each eval's
        // validate fans out further per shape. Results land by candidate
        // index, so the merge below is order-independent.
        let evals: Vec<(TestReport, ProfileReport)> =
            run_indexed(Some(budget.as_ref()), cands.len(), |i| {
                let cand = &cands[i];
                let _in_flight = probe.enter();
                let tests =
                    tester.validate_with(spec, &cand.kernel, &suite, Some(cache));
                let profile =
                    profiler.profile(&cand.kernel, &suite, Some(&base_profile));
                (tests, profile)
            });
        candidates_evaluated += cands.len();

        // ---- gate, record, update the global best (by index) ---------
        let round_best = best_speedup;
        let mut gate = vec![false; cands.len()];
        let mut rec_idx = vec![usize::MAX; cands.len()];
        let mut any_accept = vec![false; beam.len()];
        let mut new_blocks: Vec<Vec<Move>> = vec![Vec::new(); beam.len()];
        for (si, sr) in per_state.iter().enumerate() {
            if sr.start == sr.end {
                records.push(RoundRecord {
                    round,
                    beam_state: si,
                    candidate: 0,
                    applied: None,
                    rationale: String::new(),
                    pass: true,
                    speedup_internal: round_best,
                    mean_us_internal: beam[si].profile.mean_us,
                    accepted: false,
                    loc: printer::loc(&beam[si].kernel),
                    note: format!(
                        "no applicable suggestion ({})",
                        sr.reasons.join("; ")
                    ),
                });
                continue;
            }
            for ci in sr.start..sr.end {
                let cand = &cands[ci];
                let (tests, profile) = &evals[ci];
                let speedup = profile.speedup_vs_baseline;
                let improved = speedup >= round_best * ACCEPT_THRESHOLD;
                let accepted = tests.pass && improved;
                let note = if !tests.pass {
                    match &tests.failure {
                        Some(f) => format!("rejected: runtime failure ({f})"),
                        None => format!(
                            "rejected: numerical mismatch (rel {:.2e})",
                            tests.max_rel_err
                        ),
                    }
                } else if !improved {
                    new_blocks[si].push(cand.applied);
                    format!(
                        "rejected: measured {:.2}x vs best {:.2}x — move blocked",
                        speedup, round_best
                    )
                } else {
                    format!("accepted at {:.2}x (internal)", speedup)
                };
                gate[ci] = accepted;
                any_accept[si] = any_accept[si] || accepted;
                rec_idx[ci] = records.len();
                records.push(RoundRecord {
                    round,
                    beam_state: si,
                    candidate: cand.index,
                    applied: Some(cand.applied),
                    rationale: cand.rationale.clone(),
                    pass: tests.pass,
                    speedup_internal: speedup,
                    mean_us_internal: profile.mean_us,
                    accepted,
                    loc: printer::loc(&cand.kernel),
                    note,
                });
                if accepted && speedup > best_speedup {
                    best = cand.kernel.clone();
                    best_speedup = speedup;
                }
            }
        }

        // ---- select the next beam ------------------------------------
        let mut pool: Vec<PoolEntry> = Vec::new();
        for ci in 0..cands.len() {
            if !gate[ci] {
                continue;
            }
            let (tests, profile) = &evals[ci];
            pool.push(PoolEntry {
                state: BeamState {
                    kernel: cands[ci].kernel.clone(),
                    tests: tests.clone(),
                    profile: profile.clone(),
                    speedup: profile.speedup_vs_baseline,
                    // Fresh kernel, fresh block set: a move that did not
                    // pay on the parent may pay here.
                    blocked: Vec::new(),
                },
                score: profile.speedup_vs_baseline,
                parent: cands[ci].parent,
                cand: cands[ci].index,
                fresh: true,
                rec: rec_idx[ci],
            });
        }
        let n_states = any_accept.len();
        let mut superseded: Vec<(usize, BeamState)> = Vec::new();
        for (si, mut state) in beam.into_iter().enumerate() {
            state.blocked.append(&mut new_blocks[si]);
            if any_accept[si] {
                // Replaced by its accepted candidate(s); held back only
                // for the narrow-beam fallback below.
                superseded.push((si, state));
            } else {
                pool.push(PoolEntry {
                    score: state.speedup,
                    state,
                    parent: si,
                    cand: usize::MAX,
                    fresh: false,
                    rec: usize::MAX,
                });
            }
        }
        pool.sort_by(|a, b| {
            b.score
                .total_cmp(&a.score)
                .then_with(|| b.fresh.cmp(&a.fresh))
                .then_with(|| a.parent.cmp(&b.parent))
                .then_with(|| a.cand.cmp(&b.cand))
        });
        let mut selected: Vec<PoolEntry> = Vec::new();
        let mut child_selected = vec![false; n_states];
        for entry in pool {
            let full = selected.len() >= beam_width;
            let dup = selected
                .iter()
                .any(|s| s.state.kernel == entry.state.kernel);
            if full || dup {
                if entry.fresh && entry.rec != usize::MAX {
                    records[entry.rec].accepted = false;
                    records[entry.rec].note.push_str(if dup {
                        "; dropped: duplicate beam state"
                    } else {
                        "; dropped: beam full"
                    });
                }
                continue;
            }
            if entry.fresh {
                child_selected[entry.parent] = true;
            }
            selected.push(entry);
        }
        // Fallback: a parent whose accepted candidates all got deduped
        // or squeezed out would otherwise vanish and silently narrow
        // the beam; re-offer such parents (in index order) while room
        // remains. Unreachable at B = K = 1, where the single accepted
        // child is always selected.
        for (si, state) in superseded {
            if selected.len() >= beam_width {
                break;
            }
            if child_selected[si]
                || selected.iter().any(|s| s.state.kernel == state.kernel)
            {
                continue;
            }
            selected.push(PoolEntry {
                score: state.speedup,
                state,
                parent: si,
                cand: usize::MAX,
                fresh: false,
                rec: usize::MAX,
            });
        }
        beam = selected.into_iter().map(|e| e.state).collect();
    }

    finish_outcome(
        spec,
        cfg,
        records,
        baseline,
        best,
        cache,
        budget,
        SearchTelemetry {
            candidates_evaluated,
            peak_concurrent_evals: probe.peak(),
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{optimize, optimize_greedy};
    use crate::kernels;

    #[test]
    fn beam_matches_or_beats_greedy_on_every_kernel_default_config() {
        // The acceptance bar: the default beam configuration must never
        // ship a slower kernel than the greedy loop it generalizes, on
        // the same seed.
        for spec in kernels::all_specs() {
            let greedy_cfg = Config::multi_agent();
            let beam_cfg = Config::multi_agent_beam();
            let g = optimize(&spec, &greedy_cfg);
            let b = optimize(&spec, &beam_cfg);
            assert!(b.final_correct, "{}", spec.paper_name);
            assert!(
                b.final_speedup >= g.final_speedup * (1.0 - 1e-9),
                "{}: beam {:.3}x < greedy {:.3}x",
                spec.paper_name,
                b.final_speedup,
                g.final_speedup
            );
            assert!(
                b.candidates_evaluated > g.candidates_evaluated,
                "beam must speculate more than greedy"
            );
            // Concurrency witness: with >= 2 workers available, candidate
            // evaluations must have overlapped in flight.
            let cores = thread::available_parallelism().map_or(1, |n| n.get());
            if cores >= 2 {
                assert!(
                    b.peak_concurrent_evals >= 2,
                    "{}: candidate evaluations never overlapped (peak {})",
                    spec.paper_name,
                    b.peak_concurrent_evals
                );
            }
        }
    }

    #[test]
    fn beam_is_deterministic_despite_parallel_evaluation() {
        let cfg = Config {
            seed: 7,
            ..Config::multi_agent_beam()
        };
        let spec = kernels::merge::spec();
        let a = optimize_beam(&spec, &cfg);
        let b = optimize_beam(&spec, &cfg);
        assert_eq!(a.records, b.records);
        assert_eq!(a.best, b.best);
        assert_eq!(a.final_speedup.to_bits(), b.final_speedup.to_bits());
        assert_eq!(a.candidates_evaluated, b.candidates_evaluated);
    }

    #[test]
    fn beam_records_carry_state_and_candidate_indices() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent_beam()
        };
        let out = optimize_beam(&kernels::merge::spec(), &cfg);
        assert!(out.records.len() > cfg.rounds, "speculation widens the log");
        // Round numbers are non-decreasing and candidate indices are
        // within the configured width.
        let mut last_round = 0;
        for r in &out.records {
            assert!(r.round >= last_round);
            last_round = r.round;
            assert!(r.beam_state < cfg.beam_width);
            assert!(r.candidate < cfg.candidates_per_round);
        }
        // The first round speculates from a single state.
        assert!(out
            .records
            .iter()
            .filter(|r| r.round == 1)
            .all(|r| r.beam_state == 0));
        // Compile caching must have kicked in (duplicate candidates or
        // the final oracle pass re-validating the winner).
        assert!(out.cache_hits > 0, "cache never hit: {:?}", out.cache_hits);
    }

    #[test]
    fn wider_beam_cannot_regress_final_speedup_quiet() {
        // Quiet (deterministic) setting: widening the search may only
        // help or tie on the kernels' small move space.
        for spec in kernels::all_specs() {
            let quiet = Config {
                bug_rate: 0.0,
                temperature: 0.0,
                ..Config::multi_agent()
            };
            let wide = Config {
                beam_width: 2,
                candidates_per_round: 3,
                ..quiet.clone()
            };
            let g = optimize_beam(&spec, &quiet);
            let b = optimize_beam(&spec, &wide);
            assert!(
                b.final_speedup >= g.final_speedup * (1.0 - 1e-9),
                "{}: wide {:.3}x < greedy {:.3}x",
                spec.paper_name,
                b.final_speedup,
                g.final_speedup
            );
        }
    }

    #[test]
    fn candidate_streams_are_pairwise_distinct() {
        let mut seen = Vec::new();
        for round in 1..=3usize {
            for state in 0..3usize {
                for cand in 0..3usize {
                    let mut s = candidate_stream(42, round, state, cand);
                    seen.push(s.next_u64());
                }
            }
        }
        let mut dedup = seen.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seen.len(), "stream collision");
    }

    #[test]
    fn greedy_oracle_probe_and_cache_fields_populate() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent()
        };
        let out = optimize_greedy(&kernels::silu::spec(), &cfg);
        assert!(out.candidates_evaluated >= 1);
        assert_eq!(out.peak_concurrent_evals, 1, "greedy evaluates serially");
        assert!(out.cache_misses > 0);
    }
}
