//! The Astra coordinator — Algorithm 1 of the paper.
//!
//! Runs R rounds of the plan → code → test → profile loop over one
//! kernel, recording a `(round, code, correctness, performance)` log
//! tuple per iteration, then selects the best *correct* candidate and
//! post-processes it: re-validation and final performance measurement on
//! the representative (paper Table 4) shapes, independent of whatever
//! shapes the agents used internally — that is the paper's "validate
//! against the original framework implementation" step.
//!
//! One deviation from the literal pseudo-code, noted in DESIGN.md: when a
//! candidate fails testing or regresses on the agents' own measurements,
//! the next round continues from the best known-good kernel rather than
//! the broken one (the paper's log-based selection implies the same
//! end result; carrying a broken kernel forward would waste rounds).

pub mod run;

pub use run::{
    optimize, optimize_all_parallel, AgentMode, Config, Outcome, RoundRecord,
};
