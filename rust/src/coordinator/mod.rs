//! The Astra coordinator — Algorithm 1 of the paper.
//!
//! Runs R rounds of the plan → code → test → profile loop over one
//! kernel, recording a `(round, code, correctness, performance)` log
//! tuple per iteration, then selects the best *correct* candidate and
//! post-processes it: re-validation and final performance measurement on
//! the representative (paper Table 4) shapes, independent of whatever
//! shapes the agents used internally — that is the paper's "validate
//! against the original framework implementation" step.
//!
//! One deviation from the literal pseudo-code, noted in DESIGN.md: when a
//! candidate fails testing or regresses on the agents' own measurements,
//! the next round continues from the best known-good kernel rather than
//! the broken one (the paper's log-based selection implies the same
//! end result; carrying a broken kernel forward would waste rounds).
//!
//! Since the beam refactor, the loop generalizes Algorithm 1 to a
//! speculative beam search ([`search`]): `beam_width` known-good states
//! each speculate `candidates_per_round` planner suggestions per round,
//! all evaluated concurrently. The defaults (`B = K = 1`) reproduce the
//! paper's greedy trajectory bit-for-bit, so every paper-fidelity test
//! keeps its meaning.

pub mod run;
pub mod sched;
pub mod search;

pub use run::{
    optimize, optimize_all_parallel, optimize_all_parallel_budgeted,
    optimize_all_parallel_with_cache, optimize_greedy, optimize_scenarios,
    optimize_with_budget, optimize_with_cache, optimize_with_cache_budget,
    AgentMode, Config, Outcome, RoundRecord, ScenarioOutcome,
};
pub use search::{optimize_beam, optimize_beam_with_cache};
