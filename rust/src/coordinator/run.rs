//! The optimization loop (Algorithm 1) and its configuration.

use std::thread;

use crate::agents::{
    CodingAgent, CodingOutcome, MockLlm, PlannerPolicy, ProfilingAgent,
    SingleAgentPlanner, TestQuality, TestingAgent,
};
use crate::ir::{printer, Kernel};
use crate::kernels::KernelSpec;
use crate::sim::{self, GpuModel};
use crate::transforms::Move;

/// Multi-agent (Figure 1) or single-agent baseline (§5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentMode {
    Multi,
    Single,
}

impl std::fmt::Display for AgentMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AgentMode::Multi => write!(f, "multi-agent"),
            AgentMode::Single => write!(f, "single-agent"),
        }
    }
}

/// Coordinator configuration (§4: R = 5, o4-mini → MockLlm defaults).
#[derive(Debug, Clone)]
pub struct Config {
    pub mode: AgentMode,
    /// Optimization rounds R.
    pub rounds: usize,
    pub seed: u64,
    /// Coding-agent fumble probability (0 disables failure injection).
    pub bug_rate: f32,
    /// Planner ranking noise.
    pub temperature: f32,
    pub model: GpuModel,
}

impl Config {
    pub fn multi_agent() -> Config {
        Config {
            mode: AgentMode::Multi,
            rounds: 5,
            seed: 42,
            bug_rate: 0.1,
            temperature: 0.1,
            model: GpuModel::h100(),
        }
    }

    pub fn single_agent() -> Config {
        Config {
            mode: AgentMode::Single,
            rounds: 5,
            seed: 42,
            bug_rate: 0.1,
            // One agent juggling four roles plans with more noise.
            temperature: 0.3,
            model: GpuModel::h100(),
        }
    }
}

/// One `(round, code, correctness, performance)` log tuple plus the
/// coordinator's decision.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// Move the coding agent applied (None = nothing applicable).
    pub applied: Option<Move>,
    /// Planner rationale for the applied move.
    pub rationale: String,
    /// Testing-agent verdict.
    pub pass: bool,
    /// Speedup vs baseline *on the agents' own perf shapes*.
    pub speedup_internal: f64,
    /// Mean time on the agents' perf shapes (µs).
    pub mean_us_internal: f64,
    /// Whether the candidate was kept as the new working kernel.
    pub accepted: bool,
    pub loc: usize,
    pub note: String,
}

/// Result of optimizing one kernel.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub kernel_name: String,
    pub mode: AgentMode,
    pub records: Vec<RoundRecord>,
    pub baseline: Kernel,
    pub best: Kernel,
    /// Post-processing: geomean speedup on the representative shapes.
    pub final_speedup: f64,
    /// Per representative shape: (label, base µs, opt µs, speedup).
    pub per_shape: Vec<(String, f64, f64, f64)>,
    /// Post-processing re-validation on the oracle suite.
    pub final_correct: bool,
    pub baseline_loc: usize,
    pub best_loc: usize,
    /// Mean baseline / optimized time on representative shapes (µs).
    pub base_mean_us: f64,
    pub opt_mean_us: f64,
}

/// Accept a candidate if its measured (internal) geomean does not regress
/// beyond noise. The unrepresentative single-agent suite makes this gate
/// porous — the §5.2 mechanism.
const ACCEPT_THRESHOLD: f64 = 0.98;

/// Run Algorithm 1 on one kernel.
pub fn optimize(spec: &KernelSpec, cfg: &Config) -> Outcome {
    let quality = match cfg.mode {
        AgentMode::Multi => TestQuality::Representative,
        AgentMode::Single => TestQuality::Unrepresentative,
    };
    let tester = TestingAgent::new(quality, cfg.seed);
    let profiler = ProfilingAgent::new(cfg.model.clone());
    let mut planner: Box<dyn PlannerPolicy> = match cfg.mode {
        AgentMode::Multi => Box::new(MockLlm::new(cfg.temperature, cfg.seed)),
        AgentMode::Single => {
            Box::new(SingleAgentPlanner::new(cfg.temperature, cfg.seed))
        }
    };
    let mut coder = CodingAgent::new(cfg.bug_rate, cfg.seed ^ 0xC0DE);

    // Algorithm 1, lines 1-7: suite + baseline profile + log init.
    let baseline = (spec.build_baseline)();
    let suite = tester.generate_tests(spec);
    let base_tests = tester.validate(spec, &baseline, &suite);
    let base_profile = profiler.profile(&baseline, &suite, None);
    debug_assert!(base_tests.pass, "baseline must pass its own tests");

    let mut records = Vec::new();
    let mut best = baseline.clone();
    let mut best_speedup = 1.0f64;
    let mut cur = baseline.clone();
    let mut cur_tests = base_tests;
    let mut cur_profile = base_profile.clone();
    let mut blocked: Vec<Move> = Vec::new();

    // Lines 8-16: R rounds of suggest → apply → validate → profile.
    for round in 1..=cfg.rounds {
        let mut suggestions = planner.suggest(&cur, &cur_tests, &cur_profile);
        suggestions.retain(|s| !blocked.contains(&s.mv));
        let outcome = coder.apply(&cur, &suggestions);
        let (candidate, applied, rationale) = match outcome {
            CodingOutcome::Candidate { kernel, applied } => {
                let why = suggestions
                    .iter()
                    .find(|s| s.mv == applied)
                    .map(|s| s.rationale.clone())
                    .unwrap_or_default();
                (kernel, applied, why)
            }
            CodingOutcome::NothingApplicable { reasons } => {
                records.push(RoundRecord {
                    round,
                    applied: None,
                    rationale: String::new(),
                    pass: true,
                    speedup_internal: best_speedup,
                    mean_us_internal: cur_profile.mean_us,
                    accepted: false,
                    loc: printer::loc(&cur),
                    note: format!(
                        "no applicable suggestion ({})",
                        reasons.join("; ")
                    ),
                });
                continue;
            }
        };

        let tests = tester.validate(spec, &candidate, &suite);
        let profile = profiler.profile(&candidate, &suite, Some(&base_profile));
        let speedup = profile.speedup_vs_baseline;
        let improved = speedup >= best_speedup * ACCEPT_THRESHOLD;
        let accepted = tests.pass && improved;

        let note = if !tests.pass {
            match &tests.failure {
                Some(f) => format!("rejected: runtime failure ({f})"),
                None => format!(
                    "rejected: numerical mismatch (rel {:.2e})",
                    tests.max_rel_err
                ),
            }
        } else if !improved {
            blocked.push(applied);
            format!(
                "rejected: measured {:.2}x vs best {:.2}x — move blocked",
                speedup, best_speedup
            )
        } else {
            format!("accepted at {:.2}x (internal)", speedup)
        };

        records.push(RoundRecord {
            round,
            applied: Some(applied),
            rationale,
            pass: tests.pass,
            speedup_internal: speedup,
            mean_us_internal: profile.mean_us,
            accepted,
            loc: printer::loc(&candidate),
            note,
        });

        if accepted {
            cur = candidate;
            cur_tests = tests;
            cur_profile = profile;
            if speedup > best_speedup {
                best = cur.clone();
                best_speedup = speedup;
            }
        }
        // On rejection, continue from the best known-good kernel (see
        // module docs for the deviation note).
    }

    // Post-processing (§3.2): validate the winner against the oracle and
    // measure on the representative shapes, independent of the agents'
    // internal suite. The oracle re-validation (which itself fans out one
    // interpreter worker per shape) and the two per-shape perf sweeps are
    // independent, so they run on concurrent scoped workers; results are
    // picked up by name, keeping the outcome deterministic.
    let shapes = (spec.representative_shapes)();
    let (final_correct, base_reports, best_reports) = thread::scope(|s| {
        let correct = s.spawn(|| {
            let final_tester =
                TestingAgent::new(TestQuality::Representative, cfg.seed ^ 0xFEED);
            let final_suite = final_tester.generate_tests(spec);
            final_tester.validate(spec, &best, &final_suite).pass
        });
        let base = s.spawn(|| sim::profile_shapes(&cfg.model, &baseline, &shapes));
        let opt = s.spawn(|| sim::profile_shapes(&cfg.model, &best, &shapes));
        (
            correct.join().expect("oracle re-validation worker panicked"),
            base.join().expect("baseline profile worker panicked"),
            opt.join().expect("optimized profile worker panicked"),
        )
    });
    let per_shape: Vec<(String, f64, f64, f64)> = shapes
        .iter()
        .zip(base_reports.iter().zip(&best_reports))
        .map(|(d, (b, o))| {
            (
                spec.shape_label(d),
                b.total_us,
                o.total_us,
                b.total_us / o.total_us,
            )
        })
        .collect();
    let final_speedup = sim::geomean_speedup(&base_reports, &best_reports);
    let base_mean_us =
        base_reports.iter().map(|r| r.total_us).sum::<f64>() / shapes.len() as f64;
    let opt_mean_us =
        best_reports.iter().map(|r| r.total_us).sum::<f64>() / shapes.len() as f64;

    Outcome {
        kernel_name: spec.paper_name.to_string(),
        mode: cfg.mode,
        records,
        baseline_loc: printer::loc(&baseline),
        best_loc: printer::loc(&best),
        baseline,
        best,
        final_speedup,
        per_shape,
        final_correct,
        base_mean_us,
        opt_mean_us,
    }
}

/// Optimize all three kernels concurrently (one coordinator per kernel on
/// its own OS thread — the process topology Rust owns at L3).
pub fn optimize_all_parallel(cfg: &Config) -> Vec<Outcome> {
    let specs = crate::kernels::all_specs();
    let handles: Vec<_> = specs
        .into_iter()
        .map(|spec| {
            let cfg = cfg.clone();
            thread::spawn(move || optimize(&spec, &cfg))
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("coordinator thread panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;

    fn quiet_multi() -> Config {
        Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent()
        }
    }

    #[test]
    fn multi_agent_improves_all_kernels() {
        let cfg = quiet_multi();
        for spec in kernels::all_specs() {
            let out = optimize(&spec, &cfg);
            assert!(out.final_correct, "{}", spec.paper_name);
            assert!(
                out.final_speedup > 1.15,
                "{}: {:.2}x",
                spec.paper_name,
                out.final_speedup
            );
            assert!(out.best_loc >= out.baseline_loc);
            assert_eq!(out.records.len(), 5, "R=5 rounds logged");
        }
    }

    #[test]
    fn log_round_numbers_are_sequential() {
        let out = optimize(&kernels::silu::spec(), &quiet_multi());
        for (i, r) in out.records.iter().enumerate() {
            assert_eq!(r.round, i + 1);
        }
    }

    #[test]
    fn single_agent_regresses_on_complex_kernel() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::single_agent()
        };
        let out = optimize(&kernels::merge::spec(), &cfg);
        // Table 3 kernel 1: SA = 0.73x. Correct but slower.
        assert!(out.final_correct);
        assert!(
            out.final_speedup < 0.95,
            "SA must regress on merge: {:.2}x",
            out.final_speedup
        );
    }

    #[test]
    fn single_agent_is_fine_on_simple_kernel() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::single_agent()
        };
        let out = optimize(&kernels::silu::spec(), &cfg);
        assert!(out.final_correct);
        assert!(
            out.final_speedup > 1.2,
            "SA on silu: {:.2}x",
            out.final_speedup
        );
    }

    #[test]
    fn injected_bugs_never_escape_the_gate() {
        // Even with an absurd fumble rate, the shipped kernel validates.
        let cfg = Config {
            bug_rate: 0.9,
            ..quiet_multi()
        };
        for spec in kernels::all_specs() {
            let out = optimize(&spec, &cfg);
            assert!(out.final_correct, "{}", spec.paper_name);
            assert!(out.final_speedup >= 0.99);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quiet_multi();
        let a = optimize(&kernels::rmsnorm::spec(), &cfg);
        let b = optimize(&kernels::rmsnorm::spec(), &cfg);
        assert_eq!(a.final_speedup, b.final_speedup);
        assert_eq!(a.best, b.best);
    }

    #[test]
    fn parallel_driver_covers_all_kernels() {
        let outs = optimize_all_parallel(&quiet_multi());
        assert_eq!(outs.len(), 3);
        let names: Vec<_> = outs.iter().map(|o| o.kernel_name.clone()).collect();
        assert!(names.contains(&"merge_attn_states_lse".to_string()));
    }
}
