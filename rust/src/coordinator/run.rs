//! The optimization loop (Algorithm 1) and its configuration.
//!
//! [`optimize`] runs the speculative beam engine in [`super::search`];
//! at the default `beam_width = 1, candidates_per_round = 1` it
//! reproduces the paper's greedy loop bit-for-bit. The literal greedy
//! loop survives here as [`optimize_greedy`] — the differential oracle
//! (`rust/tests/beam_differential.rs`), mirroring how
//! `interp::reference` backs the compiled machine.

use std::sync::Arc;

use crate::agents::{CodingAgent, ProfilingAgent, TestQuality, TestingAgent};
use crate::faults::{self, FaultPlan, FaultStats};
use crate::interp::budget::run_indexed;
use crate::interp::{CompileCache, WorkerBudget};
use crate::ir::{printer, Kernel};
use crate::kernels::KernelSpec;
use crate::sim::GpuModel;
use crate::transforms::Move;

use super::search::{self, SearchTelemetry};

/// Multi-agent (Figure 1) or single-agent baseline (§5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentMode {
    Multi,
    Single,
}

impl std::fmt::Display for AgentMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AgentMode::Multi => write!(f, "multi-agent"),
            AgentMode::Single => write!(f, "single-agent"),
        }
    }
}

/// Coordinator configuration (§4: R = 5, o4-mini → MockLlm defaults).
#[derive(Debug, Clone)]
pub struct Config {
    pub mode: AgentMode,
    /// Optimization rounds R.
    pub rounds: usize,
    pub seed: u64,
    /// Coding-agent fumble probability (0 disables failure injection).
    pub bug_rate: f32,
    /// Planner ranking noise.
    pub temperature: f32,
    /// Beam width B: known-good states carried between rounds
    /// (1 = the paper's greedy Algorithm 1).
    pub beam_width: usize,
    /// Top-K planner suggestions speculatively materialized and
    /// evaluated concurrently per beam state per round (the *ceiling*
    /// when the adaptive scheduler is on).
    pub candidates_per_round: usize,
    /// Adaptive speculation scheduler: size each round's candidate set
    /// from the planner's normalized priority gap
    /// ([`crate::agents::priority_gap`]) — tied suggestions get the
    /// full `candidates_per_round`, a dominant one only
    /// `adaptive_min_candidates`. Off (the default) is the static
    /// schedule, byte-for-byte.
    pub adaptive_candidates: bool,
    /// K floor for the adaptive scheduler (clamped to
    /// `1..=candidates_per_round` at use).
    pub adaptive_min_candidates: usize,
    /// Normalized priority gap at (and beyond) which the adaptive K
    /// hits its floor; gaps below it interpolate linearly up to the
    /// ceiling. `0` disables the shrink entirely — adaptive mode with
    /// threshold 0 reproduces the static schedule bit-for-bit
    /// (pinned in `tests/beam_differential.rs`).
    pub adaptive_gap_threshold: f64,
    /// Beam-round cancellation: once this many candidates of a round
    /// have fully evaluated *and* one of them measured strictly better
    /// than the global best at round start, a per-round token (layered
    /// over each candidate's validation token) abandons still-running
    /// sibling validations. A deterministic repair pass re-runs any
    /// candidate the canonical (index-order) schedule keeps, so
    /// outcomes are byte-identical at every worker count/budget.
    /// `0` (the default) never cancels — today's behavior exactly.
    pub round_budget: usize,
    /// Worker threads the interpreter fans over each launch's blocks
    /// during validation (`1` = the serial engine byte-for-byte, `0` =
    /// auto — the testing agent picks per launch from the compiled
    /// grid: serial below 4 blocks, one per core above). For kernels
    /// honoring the CUDA contract that blocks never *read* another
    /// block's writes — every kernel the baselines, transforms and
    /// fault injection can produce, differential-wall pinned — outcomes
    /// are byte-identical at every setting.
    pub grid_workers: usize,
    /// Process-wide worker budget: the cap on live interpreter threads
    /// across all nested fan-outs (candidates × shapes × grid workers).
    /// `0` = one per available core (the default). Budget capacity only
    /// changes scheduling, never a trajectory (every fan-out merges by
    /// index; test-pinned below).
    pub worker_budget: usize,
    /// Deterministic fault-injection plan (chaos hardening; see
    /// [`crate::faults`]). The default plan is read from the
    /// `ASTRA_FAULT_RATE`/`ASTRA_FAULT_SEED`/`ASTRA_FAULT_SITES`
    /// environment (the chaos-CI surface) and is disabled when those
    /// are unset — a zero-cost no-op, bit-for-bit today's engine.
    pub fault: FaultPlan,
    /// Step-denominated per-candidate watchdog: cumulative interpreter
    /// step budget per correctness launch during in-loop validation
    /// (`0` = the interpreter's own [`crate::interp::STEP_LIMIT`]).
    /// Runaway candidates trip an `IterationLimit` error instead of
    /// hanging a round; the final oracle re-validation is *not* capped.
    pub watchdog_steps: u64,
    /// Quarantine a beam lineage after this many consecutive rounds in
    /// which every one of its materialized candidates failed: the state
    /// stops planning (its rounds log constant `quarantined:` records)
    /// but keeps serving its known-good kernel. `0` (the default)
    /// disables quarantine.
    pub quarantine_after: usize,
    /// Pipelined rounds (Block-STM-style speculation across the round
    /// barrier): a pool of budget-governed workers drains a
    /// smallest-index-first task queue, and planning for round N+1
    /// starts from the current provisional winner before round N
    /// settles. When the settled winner differs from the prediction,
    /// only the stale speculated lineage aborts and re-executes.
    /// Outcomes are byte-identical to the barriered engine at every
    /// `(grid_workers, worker_budget, fault plan)` point (pinned in
    /// `tests/beam_differential.rs`). Off (the default) runs the
    /// literal legacy engine.
    pub pipelined: bool,
    /// How many rounds ahead the pipelined engine may speculate
    /// (`0` disables speculation even with `pipelined` set — the
    /// legacy barriered engine runs verbatim).
    pub speculation_depth: usize,
    /// Concurrent client streams for `astra serve` (`0` = the legacy
    /// single-stream PJRT serve loop; `>= 1` selects the interp-backed
    /// concurrent harness in [`crate::pipeline::serve`]).
    pub clients: usize,
    /// Request mix the concurrent clients draw from (weights over the
    /// serving kernel classes, deterministic per client stream).
    pub request_mix: crate::pipeline::RequestMix,
    /// Background online re-optimization during concurrent serving:
    /// an optimizer thread keeps searching and hot-swaps gate-validated
    /// better variants through the routing table.
    pub online_optimize: bool,
    /// Timed-step interval between hot-swap publish checkpoints in the
    /// concurrent harness (must be `>= 1`; checkpoints block on the
    /// optimizer so swap epochs land at deterministic step indices).
    pub swap_interval: usize,
    /// Crash-consistent on-disk artifact store directory (`--store
    /// DIR`; `None` disables persistence — bit-for-bit today's engine).
    /// With a store, `optimize` journals every settled round, skips
    /// already-validated candidates, and warm-starts from the best
    /// recorded trajectory; a fresh (or corrupt) store never changes
    /// the shipped kernel, only timings and the `store_*` ledger
    /// counters (pinned in `tests/store_recovery.rs`).
    pub store_dir: Option<String>,
    /// Reconstruct a killed store-backed run from its journal and
    /// continue it byte-identically to an uninterrupted run (requires
    /// `store_dir`; no journal for this run key = plain cold start).
    pub resume: bool,
    /// Crash-drill hook (`ASTRA_KILL_AFTER_ROUND`, CI only): abort the
    /// search right after journaling this round, `0` = off.
    /// Deliberately *not* part of the rendered config, so the killed
    /// run and its resume twin share one journal run key.
    pub kill_after_round: usize,
    /// Per-scenario search (`--scenarios split`): run one optimization
    /// per [`crate::kernels::Scenario`] bucket instead of one per
    /// kernel, each retargeted at that bucket's dim set via
    /// [`KernelSpec::with_shapes`]. Off (`"global"`, the default) runs
    /// exactly one search per kernel on the paper's representative
    /// shapes — bit-for-bit the legacy engine.
    pub scenario_split: bool,
    /// Per-scenario dispatch in `astra serve` (`--dispatch`): route
    /// each request's launch shape through the
    /// [`crate::pipeline::DispatchTable`] bucket covering it. Off (the
    /// default) keeps every class on its single global slot — the
    /// legacy routing table byte-for-byte (pinned in
    /// `tests/dispatch.rs`).
    pub dispatch: bool,
    pub model: GpuModel,
}

impl Config {
    pub fn multi_agent() -> Config {
        Config {
            mode: AgentMode::Multi,
            rounds: 5,
            seed: 42,
            bug_rate: 0.1,
            temperature: 0.1,
            beam_width: 1,
            candidates_per_round: 1,
            adaptive_candidates: false,
            adaptive_min_candidates: 1,
            adaptive_gap_threshold: 0.5,
            round_budget: 0,
            grid_workers: 1,
            worker_budget: 0,
            fault: FaultPlan::from_env(),
            watchdog_steps: 0,
            quarantine_after: 0,
            pipelined: false,
            speculation_depth: 1,
            clients: 0,
            request_mix: crate::pipeline::RequestMix::uniform(),
            online_optimize: false,
            swap_interval: 8,
            store_dir: None,
            resume: false,
            kill_after_round: 0,
            scenario_split: false,
            dispatch: false,
            model: GpuModel::h100(),
        }
    }

    pub fn single_agent() -> Config {
        Config {
            mode: AgentMode::Single,
            rounds: 5,
            seed: 42,
            bug_rate: 0.1,
            // One agent juggling four roles plans with more noise.
            temperature: 0.3,
            ..Config::multi_agent()
        }
    }

    /// Speculative preset: the multi-agent system widened to B = 2 beam
    /// states × K = 3 concurrent candidates per state per round.
    pub fn multi_agent_beam() -> Config {
        Config {
            beam_width: 2,
            candidates_per_round: 3,
            ..Config::multi_agent()
        }
    }

    /// Adaptive-scheduler preset: the beam preset with the speculation
    /// budget spent where the planner's ranking is contested — K shrinks
    /// toward 1 as the top suggestion's normalized priority gap
    /// approaches 0.5, and a round's stragglers are cancelled once 3
    /// candidates have evaluated and one measured strictly better
    /// (EXPERIMENTS.md §Adaptive-K).
    pub fn multi_agent_adaptive() -> Config {
        Config {
            adaptive_candidates: true,
            adaptive_min_candidates: 1,
            adaptive_gap_threshold: 0.5,
            round_budget: 3,
            ..Config::multi_agent_beam()
        }
    }

    /// Pipelined preset: a single greedy-shaped lineage (B = 1) widened
    /// to K = 3 candidates per round, with rounds overlapped two deep
    /// across the barrier. B = 1 on purpose: speculation predicts the
    /// next beam from the front-runner, and a one-state beam makes the
    /// prediction commit often enough to pay (EXPERIMENTS.md
    /// §Pipelined-rounds).
    pub fn multi_agent_pipelined() -> Config {
        Config {
            pipelined: true,
            speculation_depth: 2,
            candidates_per_round: 3,
            ..Config::multi_agent()
        }
    }
}

/// One `(round, code, correctness, performance)` log tuple plus the
/// coordinator's decision. Beam search logs one record per *speculated
/// candidate* (plus one per state with nothing applicable), so a round
/// may contribute up to `beam_width × candidates_per_round` records; in
/// greedy mode (`B = K = 1`) this stays one record per round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Beam state (parent) index this record belongs to (0 in greedy).
    pub beam_state: usize,
    /// Candidate index within the beam state (0 in greedy).
    pub candidate: usize,
    /// Move the coding agent applied (None = nothing applicable).
    pub applied: Option<Move>,
    /// Planner rationale for the applied move.
    pub rationale: String,
    /// Testing-agent verdict.
    pub pass: bool,
    /// Speedup vs baseline *on the agents' own perf shapes*.
    pub speedup_internal: f64,
    /// Mean time on the agents' perf shapes (µs).
    pub mean_us_internal: f64,
    /// Whether the candidate was kept as a working kernel (a beam state
    /// for the next round; in greedy mode, the new current kernel).
    pub accepted: bool,
    pub loc: usize,
    pub note: String,
}

/// Result of optimizing one kernel.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub kernel_name: String,
    pub mode: AgentMode,
    pub records: Vec<RoundRecord>,
    pub baseline: Kernel,
    pub best: Kernel,
    /// Post-processing: geomean speedup on the representative shapes.
    pub final_speedup: f64,
    /// Per representative shape: (label, base µs, opt µs, speedup).
    pub per_shape: Vec<(String, f64, f64, f64)>,
    /// Post-processing re-validation on the oracle suite.
    pub final_correct: bool,
    pub baseline_loc: usize,
    pub best_loc: usize,
    /// Mean baseline / optimized time on representative shapes (µs).
    pub base_mean_us: f64,
    pub opt_mean_us: f64,
    /// Total speculative candidates validated + profiled (canonically
    /// abandoned candidates — see [`Config::round_budget`] — are *not*
    /// counted: their validations were cancelled, not spent).
    pub candidates_evaluated: usize,
    /// Chosen speculation width K for every planning event, in (round,
    /// beam-state) order — always the configured ceiling under the
    /// static schedule, always `1` in greedy mode. The bench folds this
    /// into the schema-v5 per-round K histogram.
    pub k_per_round: Vec<usize>,
    /// Planning events where the adaptive scheduler chose K below the
    /// configured ceiling (0 whenever adaptive mode is off or the gap
    /// threshold is 0).
    pub adaptive_k_rounds: usize,
    /// Candidates canonically abandoned by beam-round cancellation —
    /// deterministic at every worker count (0 when `round_budget` = 0).
    pub cancelled_candidates: usize,
    /// Peak number of candidate evaluations in flight at once (1 in
    /// greedy mode — the concurrency witness for the beam tests).
    pub peak_concurrent_evals: usize,
    /// Interpreter compile-cache counters for the run — exact per-run
    /// counts in every built-in path: [`optimize`] uses a private cache,
    /// and [`optimize_with_cache`] layers a private front cache over the
    /// shared one so these counters never observe sibling runs.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Injected faults observed in canonical results (0 when the fault
    /// plan is disabled). Summed per candidate in index order, so the
    /// counters are byte-identical at every worker count/budget.
    pub faults_injected: u64,
    /// Injected faults the supervision layer recovered from (a retry
    /// eventually produced a real, uninjected evaluation).
    pub faults_survived: u64,
    /// Supervised retries performed (agent calls and evaluations).
    pub retries: u64,
    /// Injected hangs converted into watchdog timeouts.
    pub watchdog_trips: u64,
    /// Beam lineages quarantined after
    /// [`Config::quarantine_after`] consecutive all-fail rounds.
    pub quarantined_lineages: u64,
    /// Round-N+1 lineages the pipelined engine planned and launched
    /// before round N settled (0 outside pipelined mode).
    pub speculated_lineages: u64,
    /// Speculated lineages whose predicted basis matched the settled
    /// round — their work was adopted wholesale.
    pub committed_lineages: u64,
    /// Speculated lineages invalidated by a settled winner that
    /// differed from the prediction — aborted and re-executed
    /// canonically.
    pub aborted_lineages: u64,
    /// Artifact-store records found valid on lookup (0 without
    /// [`Config::store_dir`]).
    pub store_hits: u64,
    /// Store lookups that found no usable record (absent or corrupt).
    pub store_misses: u64,
    /// Checksum-corrupt store entries quarantined to `*.corrupt`
    /// sidecars and recomputed cold. Corruption shifts these counters
    /// (and timings), never the shipped kernel.
    pub store_corrupt_entries: u64,
    /// Journaled rounds replayed from the store instead of re-executed
    /// (0 outside [`Config::resume`]).
    pub resumed_rounds: u64,
}

/// Accept a candidate if its measured (internal) geomean does not regress
/// beyond noise. The unrepresentative single-agent suite makes this gate
/// porous — the §5.2 mechanism.
pub(crate) const ACCEPT_THRESHOLD: f64 = 0.98;

/// Run the optimization loop on one kernel.
///
/// Always dispatches to the speculative beam engine
/// ([`search::optimize_beam`]); at the default `beam_width = 1,
/// candidates_per_round = 1` the engine's trajectory is bit-identical to
/// Algorithm 1's greedy loop (pinned by `tests/beam_differential.rs`
/// against [`optimize_greedy`]).
pub fn optimize(spec: &KernelSpec, cfg: &Config) -> Outcome {
    search::optimize_beam(spec, cfg)
}

/// [`optimize`] over a caller-owned *shared* compile cache, so launch
/// compiles of baselines and recurring candidates are reused across
/// runs — and across the three concurrent coordinators of
/// [`optimize_all_parallel`] (ROADMAP "shared cross-run compile cache").
/// The run keeps its own per-run front cache backed by `shared`
/// ([`CompileCache::with_backing`]): the trajectory *and* the
/// `Outcome::cache_{hits,misses}` counters stay byte-identical to an
/// unshared run (the counters depend only on this run's key sequence),
/// while actual compiles are shared through the backing level.
pub fn optimize_with_cache(
    spec: &KernelSpec,
    cfg: &Config,
    shared: &Arc<CompileCache>,
) -> Outcome {
    let budget = Arc::new(WorkerBudget::from_config(cfg.worker_budget));
    optimize_with_cache_budget(spec, cfg, shared, &budget)
}

/// [`optimize_with_cache`] over a caller-owned *worker budget* as well —
/// the process-wide pool the batch driver shares across coordinators
/// (and the online-optimizer thread of the concurrent serving harness,
/// which must not exceed the serving process's global thread cap).
pub fn optimize_with_cache_budget(
    spec: &KernelSpec,
    cfg: &Config,
    shared: &Arc<CompileCache>,
    budget: &Arc<WorkerBudget>,
) -> Outcome {
    let cache = CompileCache::with_backing(
        CompileCache::DEFAULT_CAPACITY,
        Arc::clone(shared),
    );
    search::optimize_beam_with_cache_budget(spec, cfg, &cache, budget)
}

/// [`optimize`] against a caller-owned worker budget, so the caller can
/// observe the pool (peak live workers) or share it across runs. The
/// budget caps scheduling only; trajectories are byte-identical at any
/// capacity (test-pinned below).
pub fn optimize_with_budget(
    spec: &KernelSpec,
    cfg: &Config,
    budget: &Arc<WorkerBudget>,
) -> Outcome {
    let cache = CompileCache::with_default_capacity();
    search::optimize_beam_with_cache_budget(spec, cfg, &cache, budget)
}

/// The literal Algorithm 1 loop — one candidate per round, evaluated
/// serially. Kept as the semantic oracle the beam engine is
/// differentially tested against (the `interp::reference` pattern);
/// `beam_width`/`candidates_per_round` are ignored here.
pub fn optimize_greedy(spec: &KernelSpec, cfg: &Config) -> Outcome {
    let quality = match cfg.mode {
        AgentMode::Multi => TestQuality::Representative,
        AgentMode::Single => TestQuality::Unrepresentative,
    };
    let budget = Arc::new(WorkerBudget::from_config(cfg.worker_budget));
    let tester = TestingAgent::new(quality, cfg.seed)
        .with_grid_workers(cfg.grid_workers)
        .with_worker_budget(Arc::clone(&budget))
        .with_step_limit(cfg.watchdog_steps);
    let profiler = ProfilingAgent::new(cfg.model.clone());
    let mut planner = search::make_planner(cfg);
    let coder = CodingAgent::new(cfg.bug_rate, cfg.seed ^ 0xC0DE);
    let cache = CompileCache::with_default_capacity();
    let probe = search::ConcurrencyProbe::new();

    // Algorithm 1, lines 1-7: suite + baseline profile + log init.
    let baseline = (spec.build_baseline)();
    let suite = tester.generate_tests(spec);
    let base_tests = tester.validate_with(spec, &baseline, &suite, Some(&cache));
    let base_profile = profiler.profile(&baseline, &suite, None);
    debug_assert!(base_tests.pass, "baseline must pass its own tests");

    let mut records = Vec::new();
    let mut best = baseline.clone();
    let mut best_speedup = 1.0f64;
    let mut cur = baseline.clone();
    let mut cur_tests = base_tests;
    let mut cur_profile = base_profile.clone();
    let mut blocked: Vec<Move> = Vec::new();
    let mut candidates_evaluated = 0usize;
    let mut k_per_round: Vec<usize> = Vec::new();
    let mut fault_stats = FaultStats::default();
    let mut quarantined_lineages = 0u64;
    let mut consec_failures = 0usize;

    // Lines 8-16: R rounds of suggest → apply → validate → profile.
    for round in 1..=cfg.rounds {
        if cfg.quarantine_after > 0 && consec_failures >= cfg.quarantine_after {
            // Quarantined lineage (mirrors the beam engine at B = 1):
            // no planning, a constant record, the known-good kernel
            // keeps serving.
            records.push(RoundRecord {
                round,
                beam_state: 0,
                candidate: 0,
                applied: None,
                rationale: String::new(),
                pass: true,
                speedup_internal: best_speedup,
                mean_us_internal: cur_profile.mean_us,
                accepted: false,
                loc: printer::loc(&cur),
                note: format!(
                    "quarantined: lineage disabled after {} \
                     consecutive failed rounds",
                    cfg.quarantine_after
                ),
            });
            continue;
        }
        let mut suggestions = planner.suggest(&cur, &cur_tests, &cur_profile);
        suggestions.retain(|s| !blocked.contains(&s.mv));
        // The greedy loop plans exactly once per (non-quarantined)
        // round with K = 1 — the beam engine at B = K = 1 mirrors this
        // exactly (differential wall).
        k_per_round.push(1);
        // First applicable suggestion, fumble roll from the same derived
        // per-candidate stream the beam engine uses for (round, 0, 0).
        let mut materialized: Option<(Kernel, Move, String)> = None;
        let mut reasons = Vec::new();
        for (pos, s) in suggestions.iter().enumerate() {
            if let Err(reason) = search::supervised_agent_gate(
                cfg.fault,
                faults::mix(faults::candidate_key(round, 0, 0), pos as u64),
                &mut fault_stats,
            ) {
                reasons.push(reason);
                continue;
            }
            let mut stream = search::candidate_stream(cfg.seed, round, 0, 0);
            match coder.apply_one(&cur, s, &mut stream) {
                Ok(k) => {
                    materialized = Some((k, s.mv, s.rationale.clone()));
                    break;
                }
                Err(e) => reasons.push(e),
            }
        }
        let Some((candidate, applied, rationale)) = materialized else {
            records.push(RoundRecord {
                round,
                beam_state: 0,
                candidate: 0,
                applied: None,
                rationale: String::new(),
                pass: true,
                speedup_internal: best_speedup,
                mean_us_internal: cur_profile.mean_us,
                accepted: false,
                loc: printer::loc(&cur),
                note: format!(
                    "no applicable suggestion ({})",
                    reasons.join("; ")
                ),
            });
            continue;
        };

        // Same supervised evaluation (and panic containment) as the
        // beam engine's uncancelled path, at the greedy key
        // (round, 0, 0) — injected faults replay identically.
        let key = faults::candidate_key(round, 0, 0);
        let product = {
            let _in_flight = probe.enter();
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || {
                    search::evaluate_supervised(
                        spec,
                        cfg,
                        &tester,
                        &profiler,
                        &candidate,
                        &suite,
                        Some(&base_profile),
                        Some(&cache),
                        None,
                        None,
                        key,
                    )
                },
            )) {
                Ok(product) => product
                    .expect("greedy evaluation runs without cancellation"),
                Err(p) => search::panicked_product(
                    &profiler,
                    &candidate,
                    &suite,
                    Some(&base_profile),
                    &crate::interp::budget::panic_message(p),
                ),
            }
        };
        candidates_evaluated += 1;
        fault_stats.add(&product.stats);
        let (tests, profile) = (product.tests, product.profile);
        if tests.pass {
            consec_failures = 0;
        } else {
            consec_failures += 1;
            if cfg.quarantine_after > 0
                && consec_failures == cfg.quarantine_after
            {
                quarantined_lineages += 1;
            }
        }
        let speedup = profile.speedup_vs_baseline;
        let improved = speedup >= best_speedup * ACCEPT_THRESHOLD;
        let accepted = tests.pass && improved;

        let note = if !tests.pass {
            match &tests.failure {
                Some(f) => format!("rejected: runtime failure ({f})"),
                None => format!(
                    "rejected: numerical mismatch (rel {:.2e})",
                    tests.max_rel_err
                ),
            }
        } else if !improved {
            blocked.push(applied);
            format!(
                "rejected: measured {:.2}x vs best {:.2}x — move blocked",
                speedup, best_speedup
            )
        } else {
            format!("accepted at {:.2}x (internal)", speedup)
        };

        records.push(RoundRecord {
            round,
            beam_state: 0,
            candidate: 0,
            applied: Some(applied),
            rationale,
            pass: tests.pass,
            speedup_internal: speedup,
            mean_us_internal: profile.mean_us,
            accepted,
            loc: printer::loc(&candidate),
            note,
        });

        if accepted {
            // The kernel changed, so previously non-improving moves may
            // pay again: stale blocks are dropped (they used to persist
            // for all remaining rounds — the stale-block bug).
            blocked.clear();
            cur = candidate;
            cur_tests = tests;
            cur_profile = profile;
            if speedup > best_speedup {
                best = cur.clone();
                best_speedup = speedup;
            }
        }
        // On rejection, continue from the best known-good kernel (see
        // module docs for the deviation note).
    }

    // Post-processing (§3.2) is shared with the beam engine.
    search::finish_outcome(
        spec,
        cfg,
        records,
        baseline,
        best,
        &cache,
        &budget,
        SearchTelemetry {
            candidates_evaluated,
            peak_concurrent_evals: probe.peak(),
            k_per_round,
            adaptive_k_rounds: 0,
            cancelled_candidates: 0,
            fault_stats,
            quarantined_lineages,
            speculation: search::SpecLedger::default(),
            store: search::StoreLedger::default(),
        },
    )
}

/// One scenario bucket's search result: the bucket it targeted plus the
/// full [`Outcome`] of the search run on that bucket's dim set.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    /// Bucket name (`"global"` when scenario splitting is off).
    pub scenario: &'static str,
    /// Index into `(spec.scenarios)()` (0 when splitting is off —
    /// matching [`crate::kernels::KernelSpec::scenario_of`]'s answer
    /// for every shape under a single-bucket table).
    pub scenario_index: usize,
    /// The bucket's `min_lead` floor, for dispatch-table construction.
    pub min_lead: i64,
    pub outcome: Outcome,
}

/// Run one search per scenario bucket of `spec` — the per-scenario
/// analogue of [`optimize_with_cache_budget`], sharing the same compile
/// cache, worker budget, store warm-start and chaos supervision across
/// buckets. With `cfg.scenario_split` off this is exactly one search on
/// the paper's representative shapes (the `"global"` bucket), so the
/// shipped kernel is byte-identical to the legacy single-slot engine
/// (pinned in `tests/dispatch.rs`).
pub fn optimize_scenarios(
    spec: &KernelSpec,
    cfg: &Config,
    cache: &Arc<CompileCache>,
    budget: &Arc<WorkerBudget>,
) -> Vec<ScenarioOutcome> {
    let buckets = if cfg.scenario_split {
        (spec.scenarios)()
    } else {
        vec![spec.global_scenario()]
    };
    buckets
        .into_iter()
        .enumerate()
        .map(|(i, bucket)| {
            let scoped = spec.with_shapes(bucket.shapes);
            ScenarioOutcome {
                scenario: bucket.name,
                scenario_index: i,
                min_lead: bucket.min_lead,
                outcome: optimize_with_cache_budget(&scoped, cfg, cache, budget),
            }
        })
        .collect()
}

/// Optimize every catalog kernel concurrently (one coordinator per kernel on
/// its own OS thread — the process topology Rust owns at L3). The three
/// coordinators share one compile cache, so a kernel's launch compiles
/// are done once per (kernel, dims) across the whole batch, and one
/// process-wide worker budget, so the batch's nested fan-outs
/// (coordinators × candidates × shapes × grid workers) never
/// oversubscribe the machine.
pub fn optimize_all_parallel(cfg: &Config) -> Vec<Outcome> {
    let cache = Arc::new(CompileCache::with_default_capacity());
    optimize_all_parallel_with_cache(cfg, &cache)
}

/// [`optimize_all_parallel`] over a caller-owned shared cache: repeated
/// batches (bench sweeps, table regeneration, serving pre-validation)
/// reuse each other's compiles — a second identical batch misses zero
/// times (pinned by `tests/proptests.rs`).
pub fn optimize_all_parallel_with_cache(
    cfg: &Config,
    cache: &Arc<CompileCache>,
) -> Vec<Outcome> {
    let budget = Arc::new(WorkerBudget::from_config(cfg.worker_budget));
    optimize_all_parallel_budgeted(cfg, cache, &budget)
}

/// [`optimize_all_parallel_with_cache`] over a caller-owned worker
/// budget — the kernels form a work queue drained by `1 + granted`
/// coordinator threads (the caller is the first), so even the
/// top-level coordinators respect the process-wide cap. Outcomes land
/// by kernel index: scheduling never reorders results.
pub fn optimize_all_parallel_budgeted(
    cfg: &Config,
    cache: &Arc<CompileCache>,
    budget: &Arc<WorkerBudget>,
) -> Vec<Outcome> {
    let specs = crate::kernels::all_specs();
    run_indexed(Some(budget.as_ref()), specs.len(), |i| {
        optimize_with_cache_budget(&specs[i], cfg, cache, budget)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;
    use std::thread;

    fn quiet_multi() -> Config {
        Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent()
        }
    }

    #[test]
    fn multi_agent_improves_all_kernels() {
        let cfg = quiet_multi();
        for spec in kernels::all_specs() {
            let out = optimize(&spec, &cfg);
            assert!(out.final_correct, "{}", spec.paper_name);
            assert!(
                out.final_speedup > 1.15,
                "{}: {:.2}x",
                spec.paper_name,
                out.final_speedup
            );
            assert!(out.best_loc >= out.baseline_loc);
            assert_eq!(out.records.len(), 5, "R=5 rounds logged");
        }
    }

    #[test]
    fn log_round_numbers_are_sequential() {
        let out = optimize(&kernels::silu::spec(), &quiet_multi());
        for (i, r) in out.records.iter().enumerate() {
            assert_eq!(r.round, i + 1);
        }
    }

    #[test]
    fn single_agent_regresses_on_complex_kernel() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::single_agent()
        };
        let out = optimize(&kernels::merge::spec(), &cfg);
        // Table 3 kernel 1: SA = 0.73x. Correct but slower.
        assert!(out.final_correct);
        assert!(
            out.final_speedup < 0.95,
            "SA must regress on merge: {:.2}x",
            out.final_speedup
        );
    }

    #[test]
    fn single_agent_is_fine_on_simple_kernel() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::single_agent()
        };
        let out = optimize(&kernels::silu::spec(), &cfg);
        assert!(out.final_correct);
        assert!(
            out.final_speedup > 1.2,
            "SA on silu: {:.2}x",
            out.final_speedup
        );
    }

    #[test]
    fn injected_bugs_never_escape_the_gate() {
        // Even with an absurd fumble rate, the shipped kernel validates.
        let cfg = Config {
            bug_rate: 0.9,
            ..quiet_multi()
        };
        for spec in kernels::all_specs() {
            let out = optimize(&spec, &cfg);
            assert!(out.final_correct, "{}", spec.paper_name);
            assert!(out.final_speedup >= 0.99);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quiet_multi();
        let a = optimize(&kernels::rmsnorm::spec(), &cfg);
        let b = optimize(&kernels::rmsnorm::spec(), &cfg);
        assert_eq!(a.final_speedup, b.final_speedup);
        assert_eq!(a.best, b.best);
    }

    #[test]
    fn parallel_driver_covers_all_kernels() {
        let outs = optimize_all_parallel(&quiet_multi());
        assert_eq!(outs.len(), 5);
        let names: Vec<_> = outs.iter().map(|o| o.kernel_name.clone()).collect();
        assert!(names.contains(&"merge_attn_states_lse".to_string()));
    }

    #[test]
    fn shared_cache_serves_a_second_batch_without_recompiling() {
        // Cross-run reuse: the second identical batch finds every
        // (kernel, dims) compile already resident.
        let cfg = Config {
            rounds: 2,
            ..quiet_multi()
        };
        let cache = Arc::new(CompileCache::with_default_capacity());
        let a = optimize_all_parallel_with_cache(&cfg, &cache);
        let first = cache.stats();
        assert!(first.misses > 0, "first batch must compile something");
        let b = optimize_all_parallel_with_cache(&cfg, &cache);
        let second = cache.stats();
        assert_eq!(
            second.misses, first.misses,
            "second batch must be hit-only"
        );
        assert!(second.hits > first.hits);
        // Sharing the cache never changes trajectories — and the per-run
        // front cache keeps Outcome counters identical to an unshared
        // run, concurrency notwithstanding.
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.records, y.records);
            assert_eq!(x.best, y.best);
            assert_eq!(x.cache_hits, y.cache_hits);
            assert_eq!(x.cache_misses, y.cache_misses);
        }
        let solo = optimize(&kernels::silu::spec(), &cfg);
        let shared_silu = a
            .iter()
            .find(|o| o.kernel_name == "silu_and_mul")
            .expect("silu outcome present");
        assert_eq!(solo.cache_hits, shared_silu.cache_hits);
        assert_eq!(solo.cache_misses, shared_silu.cache_misses);
    }

    #[test]
    fn worker_budget_caps_live_threads_under_beam_settings() {
        // The acceptance scenario: B=2, K=3, 3 correctness shapes, 8
        // grid workers — unbudgeted this wants dozens of threads; the
        // pool must hold the line at the configured cap. Since the
        // budgeted post-processing refactor this covers the whole run
        // including `finish_outcome`'s tail (oracle re-validation + two
        // profile sweeps now route through the same pool; the serial
        // witness for the tail alone lives in `search.rs`).
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            grid_workers: 8,
            worker_budget: 3,
            ..Config::multi_agent_beam()
        };
        let budget = Arc::new(WorkerBudget::from_config(cfg.worker_budget));
        let out = optimize_with_budget(&kernels::silu::spec(), &cfg, &budget);
        assert!(out.final_correct);
        assert!(
            budget.peak_live() <= 3,
            "budget must cap live interpreter threads: peak {}",
            budget.peak_live()
        );
        if thread::available_parallelism().map_or(1, |n| n.get()) >= 2 {
            assert!(
                budget.peak_live() >= 2,
                "granted tokens should actually be used: peak {}",
                budget.peak_live()
            );
        }
    }

    #[test]
    fn budget_capacity_never_changes_trajectories() {
        // ∞, per-core (the default) and fully-serial must agree byte
        // for byte — the budget schedules, it never selects.
        let spec = kernels::rmsnorm::spec();
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            grid_workers: 2,
            ..Config::multi_agent_beam()
        };
        let unlimited = Arc::new(WorkerBudget::unlimited());
        let a = optimize_with_budget(&spec, &cfg, &unlimited);
        for knob in [0usize, 1] {
            let budget = Arc::new(WorkerBudget::from_config(knob));
            let b = optimize_with_budget(&spec, &cfg, &budget);
            assert_eq!(a.records, b.records, "budget knob {knob}");
            assert_eq!(a.best, b.best, "budget knob {knob}");
            assert_eq!(
                a.final_speedup.to_bits(),
                b.final_speedup.to_bits(),
                "budget knob {knob}"
            );
            assert_eq!(a.final_correct, b.final_correct);
        }
    }

    #[test]
    fn serial_budget_batch_still_covers_all_kernels_in_order() {
        let cfg = Config {
            rounds: 1,
            worker_budget: 1,
            ..quiet_multi()
        };
        let a = optimize_all_parallel(&cfg);
        let b = optimize_all_parallel(&Config {
            worker_budget: 0,
            ..cfg.clone()
        });
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kernel_name, y.kernel_name, "index order is stable");
            assert_eq!(x.records, y.records);
            assert_eq!(x.best, y.best);
        }
    }

    #[test]
    fn scenario_split_off_is_one_global_search() {
        let cfg = Config {
            rounds: 2,
            ..quiet_multi()
        };
        let cache = Arc::new(CompileCache::with_default_capacity());
        let budget = Arc::new(WorkerBudget::from_config(0));
        let spec = kernels::rmsnorm::spec();
        let outs = optimize_scenarios(&spec, &cfg, &cache, &budget);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].scenario, "global");
        assert_eq!(outs[0].scenario_index, 0);
        // The global bucket is the representative shapes, so the run is
        // byte-identical to the legacy single-search engine.
        let legacy = optimize_with_cache_budget(&spec, &cfg, &cache, &budget);
        assert_eq!(outs[0].outcome.best, legacy.best);
        assert_eq!(outs[0].outcome.records, legacy.records);
    }

    #[test]
    fn scenario_split_runs_one_search_per_bucket() {
        let cfg = Config {
            rounds: 2,
            scenario_split: true,
            ..quiet_multi()
        };
        let cache = Arc::new(CompileCache::with_default_capacity());
        let budget = Arc::new(WorkerBudget::from_config(0));
        let spec = kernels::rmsnorm::spec();
        let outs = optimize_scenarios(&spec, &cfg, &cache, &budget);
        assert_eq!(outs.len(), (spec.scenarios)().len());
        for (o, b) in outs.iter().zip((spec.scenarios)()) {
            assert_eq!(o.scenario, b.name);
            assert_eq!(o.min_lead, b.min_lead);
            assert!(o.outcome.final_correct, "{}", b.name);
            // Each bucket's final numbers come from its own dim set.
            assert_eq!(o.outcome.per_shape.len(), b.shapes.len());
        }
    }

    #[test]
    fn grid_parallel_validation_keeps_greedy_outcomes_identical() {
        // The coordinator-level serial-parity claim: grid_workers only
        // changes wall clock, never a trajectory.
        let base = optimize_greedy(&kernels::silu::spec(), &quiet_multi());
        for gw in [2usize, 7] {
            let cfg = Config {
                grid_workers: gw,
                ..quiet_multi()
            };
            let out = optimize_greedy(&kernels::silu::spec(), &cfg);
            assert_eq!(base.records, out.records, "gw={gw}");
            assert_eq!(base.best, out.best, "gw={gw}");
            assert_eq!(
                base.final_speedup.to_bits(),
                out.final_speedup.to_bits(),
                "gw={gw}"
            );
        }
    }
}
