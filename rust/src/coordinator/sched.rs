//! Pipelined rounds: Block-STM-style speculation across the round
//! barrier (ROADMAP item 1).
//!
//! The barriered engine (`search.rs`) fully settles round N before
//! planning round N+1, so the tail of a round — one straggling
//! validation — idles every other worker. This module overlaps rounds
//! instead: a pool of budget-governed workers drains a
//! smallest-index-first [`TaskQueue`] of *execution* tasks, and as soon
//! as a round's **basis** results land (the candidates a prediction
//! needs), the scheduler predicts the next beam from the current
//! provisional winner, plans round N+1 against it with a *snapshotted*
//! planner, and pushes the speculated round's tasks behind the
//! canonical round's in queue order. When round N settles:
//!
//! * if the settled selection (and global best) match the prediction,
//!   the speculated round **commits** — its plan, planner mutations and
//!   already-running evaluations are adopted wholesale;
//! * otherwise only the stale lineage **aborts**: cancellation tokens
//!   abandon its in-flight validations mid-sweep
//!   ([`TestingAgent::validate_cancellable`],
//!   [`ProfilingAgent::profile_cancellable`]) and round N+1 re-plans
//!   and re-executes canonically.
//!
//! Determinism contract — byte-identical to the barriered engine at
//! every `(grid_workers, worker_budget, fault plan)` point, pinned by
//! `tests/beam_differential.rs`:
//!
//! * planning, settling and selection go through the *same seams*
//!   ([`plan_round`], [`evaluate_supervised`], [`settle_round`]) — the
//!   scheduler changes when work runs, never what runs;
//! * speculation is **invisible on abort** (aborted lineages are
//!   discarded unread, their planner was a snapshot) and **exact on
//!   commit** (the commit check compares the full selection identity
//!   plus the global best bits, which together pin every plan-relevant
//!   beam field);
//! * speculative evaluations validate cache-free and record their
//!   attempt keys in a probe ledger; a committed round *replays* the
//!   exact compile-cache probes the cache-carrying barriered
//!   evaluations would have made ([`TestingAgent::replay_cache_probes`])
//!   so `Outcome::cache_{hits,misses}` stay byte-identical;
//! * the speculation ledger itself is deterministic: whether round N+1
//!   was speculated when round N settles depends only on basis results
//!   (complete before any settle) and the depth/round caps, never on
//!   thread timing.
//!
//! [`TaskQueue`]: crate::interp::budget::TaskQueue
//! [`TestingAgent::validate_cancellable`]: crate::agents::TestingAgent::validate_cancellable
//! [`TestingAgent::replay_cache_probes`]: crate::agents::TestingAgent::replay_cache_probes
//! [`ProfilingAgent::profile_cancellable`]: crate::agents::ProfilingAgent::profile_cancellable
//! [`plan_round`]: super::search::plan_round
//! [`evaluate_supervised`]: super::search::evaluate_supervised
//! [`settle_round`]: super::search::settle_round

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::agents::{
    CodingAgent, PlannerPolicy, ProfilingAgent, TestQuality, TestingAgent,
};
use crate::faults::{self, FaultStats};
use crate::interp::budget::{panic_message, TaskQueue};
use crate::interp::{CompileCache, WorkerBudget};
use crate::kernels::KernelSpec;
use crate::store::EvalSlot;
use crate::transforms::Move;

use super::run::{
    AgentMode, Config, Outcome, RoundRecord, ACCEPT_THRESHOLD,
};
use super::search::{
    self, BeamState, Candidate, ConcurrencyProbe, EvalEnv, EvalProduct,
    RoundTally, SearchTelemetry, SelectedId, SpecLedger, StateRound,
};

/// Queue key: canonical rounds strictly before speculated ones, then
/// candidate index order, then registration order (lexicographic via
/// the derived `Ord` on field order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct TaskKey {
    round: usize,
    slot: usize,
    layer: u64,
}

/// One evaluation's stored outcome: the supervised product (or `None`
/// for a lineage-cancelled run) or a contained panic message.
type SlotResult = Result<Option<EvalProduct>, String>;

/// One in-flight round: the canonical front of the chain or a
/// speculated descendant.
struct Layer {
    id: u64,
    round: usize,
    cands: Arc<Vec<Candidate>>,
    per_state: Vec<StateRound>,
    /// The beam this round was planned against — actual for the
    /// canonical layer, predicted for speculated ones.
    beam: Vec<BeamState>,
    /// Global best speedup at this round's start (predicted for
    /// speculated layers; verified bit-exact at commit).
    round_best: f64,
    results: Vec<Option<SlotResult>>,
    /// Per-slot compile-cache probe ledger (attempt keys, in attempt
    /// order) recorded by speculative evaluations for commit replay.
    probes: Vec<Vec<u64>>,
    pending: usize,
    speculative: bool,
    /// Raised on abort: the lineage's in-flight validations and
    /// profile sweeps abandon at the next poll.
    lineage_cancel: Arc<AtomicBool>,
    cand_tokens: Arc<Vec<AtomicBool>>,
    /// Planner state *after* this round's plan — the snapshot the next
    /// speculation plans with, and the state the drive loop adopts on
    /// commit.
    planner_after: Option<Box<dyn PlannerPolicy>>,
    /// Plan telemetry accumulated locally (speculated layers only);
    /// folded into the run's counters on adoption, dropped on abort.
    k_per_round: Vec<usize>,
    adaptive_k_events: usize,
    gate_stats: FaultStats,
    /// The selection this layer's plan assumed (empty for canonical).
    predicted_selection: Vec<SelectedId>,
    /// The next-round spawn decision is made exactly once per layer.
    spawned_next: bool,
}

/// The layer chain, in round order (front = canonical).
struct Sched {
    layers: Vec<Layer>,
    next_id: u64,
}

/// State shared between the drive loop and the worker pool. The `done`
/// condvar pairs with the `sched` mutex: results are stored and
/// notified under it, so the collector can never miss a wakeup.
struct Shared {
    sched: Mutex<Sched>,
    queue: TaskQueue<TaskKey>,
    done: Condvar,
}

/// Everything a worker needs to execute one task.
struct PipeCtx<'a> {
    env: EvalEnv<'a>,
    cache: &'a CompileCache,
    budget: &'a WorkerBudget,
    probe: &'a ConcurrencyProbe,
    coder: &'a CodingAgent,
    shared: &'a Shared,
}

/// A resolved task: the layer handles a worker needs without holding
/// the scheduler lock while it evaluates.
struct TaskRef {
    layer_id: u64,
    round: usize,
    slot: usize,
    cands: Arc<Vec<Candidate>>,
    tokens: Arc<Vec<AtomicBool>>,
    lineage: Arc<AtomicBool>,
    speculative: bool,
}

/// Look a popped key up in the live chain; `None` for stale keys (the
/// layer aborted) or already-stored slots.
fn resolve(g: &Sched, key: TaskKey) -> Option<TaskRef> {
    let layer = g.layers.iter().find(|l| l.id == key.layer)?;
    if layer.results[key.slot].is_some() {
        return None;
    }
    Some(TaskRef {
        layer_id: layer.id,
        round: layer.round,
        slot: key.slot,
        cands: Arc::clone(&layer.cands),
        tokens: Arc::clone(&layer.cand_tokens),
        lineage: Arc::clone(&layer.lineage_cancel),
        speculative: layer.speculative,
    })
}

/// Execute one task and store its result. The evaluation modes mirror
/// the barriered engine exactly: canonical + `round_budget = 0` carries
/// the compile cache; canonical + `round_budget > 0` is cache-free with
/// (never-raised) cancellation tokens, the settle pass deriving the
/// canonical abandonment set just as it does for the racy legacy
/// schedule; speculative runs are cache-free, lineage-cancellable, and
/// record their cache-probe ledger for commit replay.
fn run_task(ctx: &PipeCtx<'_>, t: TaskRef) {
    let _live = ctx.budget.count_worker();
    let _in_flight = ctx.probe.enter();
    let cfg = ctx.env.cfg;
    let cand = &t.cands[t.slot];
    let key = faults::candidate_key(t.round, cand.parent, cand.index);
    let probes = Mutex::new(Vec::new());
    let use_cache = !t.speculative && cfg.round_budget == 0;
    let cancellable = t.speculative || cfg.round_budget > 0;
    // Speculative runs need their probe ledger for commit replay;
    // store-backed runs need it for every journaled evaluation, so a
    // killed run's barriered resume can replay exact cache traffic.
    let record_probes =
        t.speculative || (cfg.store_dir.is_some() && cfg.round_budget == 0);
    let result: SlotResult = std::panic::catch_unwind(AssertUnwindSafe(|| {
        search::evaluate_supervised(
            ctx.env.spec,
            cfg,
            ctx.env.tester,
            ctx.env.profiler,
            &cand.kernel,
            ctx.env.suite,
            Some(ctx.env.base_profile),
            use_cache.then_some(ctx.cache),
            cancellable.then(|| (&t.tokens[t.slot], &*t.lineage)),
            record_probes.then_some(&probes),
            key,
        )
    }))
    .map_err(panic_message);
    let recorded = probes.into_inner().expect("probe ledger poisoned");
    let mut g = ctx.shared.sched.lock().expect("scheduler poisoned");
    if let Some(layer) = g.layers.iter_mut().find(|l| l.id == t.layer_id) {
        if layer.results[t.slot].is_none() {
            layer.results[t.slot] = Some(result);
            layer.probes[t.slot] = recorded;
            layer.pending -= 1;
        }
    }
    // Spawn in the same critical section as the store: by the time a
    // round's last result lands (and the collector can observe
    // `pending == 0`), every spawn its basis enabled has happened —
    // the ledger's schedule-independence hinges on this.
    maybe_spawn(ctx, &mut g);
    drop(g);
    ctx.shared.done.notify_all();
}

/// Long-lived pool worker: park on the queue, resolve, execute.
fn worker_loop(ctx: &PipeCtx<'_>) {
    while let Some(key) = ctx.shared.queue.pop_wait() {
        let task = {
            let g = ctx.shared.sched.lock().expect("scheduler poisoned");
            resolve(&g, key)
        };
        if let Some(t) = task {
            run_task(ctx, t);
        }
    }
}

/// A prediction of how the deepest layer will settle.
struct Pred {
    beam: Vec<BeamState>,
    selection: Vec<SelectedId>,
    next_best: f64,
}

/// Predict the deepest layer's settled beam from its basis results
/// alone — pure and deterministic. Abstains (`None`) whenever any
/// settle-relevant fact is not yet knowable: canonical round-budget
/// abandonment possible, a basis result missing or panicked, a
/// rejected basis with unevaluated siblings (their fates decide the
/// parent's survival), or predicted kernels that collide (the settle
/// dedup would race hidden siblings).
fn predict(cfg: &Config, layer: &Layer) -> Option<Pred> {
    if cfg.round_budget > 0 && layer.cands.len() > cfg.round_budget {
        return None;
    }
    struct Entry {
        state: BeamState,
        score: f64,
        parent: usize,
        cand: usize,
        fresh: bool,
    }
    let mut entries: Vec<Entry> = Vec::new();
    let mut next_best = layer.round_best;
    for (si, sr) in layer.per_state.iter().enumerate() {
        if sr.start == sr.end {
            // Nothing materialized (or quarantined): the state
            // survives untouched.
            let state = layer.beam[si].clone();
            entries.push(Entry {
                score: state.speedup,
                state,
                parent: si,
                cand: usize::MAX,
                fresh: false,
            });
            continue;
        }
        let basis = sr.start;
        let Some(Ok(Some(p))) = layer.results[basis].as_ref() else {
            return None;
        };
        let speedup = p.profile.speedup_vs_baseline;
        let accepted =
            p.tests.pass && speedup >= layer.round_best * ACCEPT_THRESHOLD;
        if accepted {
            let cand = &layer.cands[basis];
            entries.push(Entry {
                state: BeamState {
                    kernel: cand.kernel.clone(),
                    tests: p.tests.clone(),
                    profile: p.profile.clone(),
                    speedup,
                    history: {
                        let mut h = layer.beam[si].history.clone();
                        h.push(cand.applied);
                        h
                    },
                    blocked: Vec::new(),
                    consec_failures: 0,
                },
                score: speedup,
                parent: si,
                cand: cand.index,
                fresh: true,
            });
            if speedup > next_best {
                next_best = speedup;
            }
        } else if sr.end - sr.start == 1 {
            // The state's only candidate was rejected: the legacy fate
            // is fully determined by the basis product.
            let mut state = layer.beam[si].clone();
            if p.tests.pass {
                state.blocked.push(layer.cands[basis].applied);
                state.consec_failures = 0;
            } else {
                state.consec_failures += 1;
            }
            entries.push(Entry {
                score: state.speedup,
                state,
                parent: si,
                cand: usize::MAX,
                fresh: false,
            });
        } else {
            return None;
        }
    }
    // The settle comparator, verbatim — stable sort from the same
    // initial order, so a committed prediction's selection order is
    // the settled order.
    entries.sort_by(|a, b| {
        b.score
            .total_cmp(&a.score)
            .then_with(|| b.fresh.cmp(&a.fresh))
            .then_with(|| a.parent.cmp(&b.parent))
            .then_with(|| a.cand.cmp(&b.cand))
    });
    for i in 0..entries.len() {
        for j in (i + 1)..entries.len() {
            if entries[i].state.kernel == entries[j].state.kernel {
                return None;
            }
        }
    }
    Some(Pred {
        selection: entries
            .iter()
            .map(|e| SelectedId {
                parent: e.parent,
                cand: e.cand,
                fresh: e.fresh,
            })
            .collect(),
        beam: entries.into_iter().map(|e| e.state).collect(),
        next_best,
    })
}

/// Spawn speculated rounds while the chain has depth headroom and the
/// deepest layer's basis is complete. Called under the scheduler lock
/// at every result store and at every judge, so the spawn schedule is
/// a pure function of (deterministic) results, never of timing.
fn maybe_spawn(ctx: &PipeCtx<'_>, g: &mut Sched) {
    while try_spawn_one(ctx, g) {}
}

fn try_spawn_one(ctx: &PipeCtx<'_>, g: &mut Sched) -> bool {
    let cfg = ctx.env.cfg;
    if g.layers.len() >= cfg.speculation_depth + 1 {
        return false;
    }
    let Some(idx) = g.layers.len().checked_sub(1) else {
        return false;
    };
    {
        let deepest = &g.layers[idx];
        if deepest.spawned_next || deepest.round >= cfg.rounds {
            return false;
        }
        for sr in &deepest.per_state {
            if sr.start < sr.end && deepest.results[sr.start].is_none() {
                // Basis incomplete: decide later (a further store
                // re-invokes us) without burning the one-shot flag.
                return false;
            }
        }
    }
    // Basis complete: the decision is final and deterministic.
    g.layers[idx].spawned_next = true;
    let Some(pred) = predict(cfg, &g.layers[idx]) else {
        return false;
    };
    let mut planner = g.layers[idx]
        .planner_after
        .as_ref()
        .expect("every layer snapshots its planner")
        .snapshot();
    let round = g.layers[idx].round + 1;
    let mut gate_stats = FaultStats::default();
    let mut k_per_round = Vec::new();
    let mut adaptive_k_events = 0usize;
    // Planning is µs-scale (MockLlm + pure transforms); holding the
    // scheduler lock keeps the spawn atomic with its trigger.
    let (cands, per_state) = search::plan_round(
        cfg,
        round,
        &pred.beam,
        planner.as_mut(),
        ctx.coder,
        &mut gate_stats,
        &mut k_per_round,
        &mut adaptive_k_events,
    );
    let id = g.next_id;
    g.next_id += 1;
    let n = cands.len();
    g.layers.push(Layer {
        id,
        round,
        cands: Arc::new(cands),
        per_state,
        beam: pred.beam,
        round_best: pred.next_best,
        results: (0..n).map(|_| None).collect(),
        probes: vec![Vec::new(); n],
        pending: n,
        speculative: true,
        lineage_cancel: Arc::new(AtomicBool::new(false)),
        cand_tokens: Arc::new(
            (0..n).map(|_| AtomicBool::new(false)).collect(),
        ),
        planner_after: Some(planner),
        k_per_round,
        adaptive_k_events,
        gate_stats,
        predicted_selection: pred.selection,
        spawned_next: false,
    });
    for slot in 0..n {
        ctx.shared.queue.push(TaskKey {
            round,
            slot,
            layer: id,
        });
    }
    true
}

/// Abort every speculated layer: raise each lineage token first, then
/// the candidate tokens (the raise-ordering contract the testing agent
/// relies on), and drop the layers — stale queue keys resolve to
/// nothing, in-flight stores find no layer.
fn abort_chain(g: &mut Sched) {
    for layer in &g.layers {
        layer.lineage_cancel.store(true, Ordering::SeqCst);
        for t in layer.cand_tokens.iter() {
            t.store(true, Ordering::SeqCst);
        }
    }
    g.layers.clear();
}

/// Wait for one layer's results, helping drain the queue meanwhile
/// (so a zero-worker grant degrades to the serial engine on the
/// caller, exactly like every other fan-out).
fn collect_layer(
    ctx: &PipeCtx<'_>,
    layer_id: u64,
) -> (Vec<SlotResult>, Vec<Vec<u64>>) {
    loop {
        {
            let g = ctx.shared.sched.lock().expect("scheduler poisoned");
            let layer = g
                .layers
                .iter()
                .find(|l| l.id == layer_id)
                .expect("the round being collected is never aborted");
            if layer.pending == 0 {
                break;
            }
        }
        if let Some(key) = ctx.shared.queue.try_pop() {
            let task = {
                let g = ctx.shared.sched.lock().expect("scheduler poisoned");
                resolve(&g, key)
            };
            if let Some(t) = task {
                run_task(ctx, t);
            }
            continue;
        }
        // Queue momentarily empty with results still pending: they are
        // in flight on pool workers. Park on the store condvar (paired
        // with the sched mutex, so the wakeup cannot be missed).
        let g = ctx.shared.sched.lock().expect("scheduler poisoned");
        let pending = g
            .layers
            .iter()
            .find(|l| l.id == layer_id)
            .map_or(0, |l| l.pending);
        if pending > 0 {
            drop(ctx.shared.done.wait(g).expect("scheduler poisoned"));
        }
    }
    let mut g = ctx.shared.sched.lock().expect("scheduler poisoned");
    let layer = g
        .layers
        .iter_mut()
        .find(|l| l.id == layer_id)
        .expect("the round being collected is never aborted");
    let results = layer
        .results
        .iter_mut()
        .map(|r| r.take().expect("pending == 0 means every slot stored"))
        .collect();
    let probes = std::mem::take(&mut layer.probes);
    (results, probes)
}

/// The pipelined engine. Dispatched from
/// [`search::optimize_beam_with_cache_budget`] when `cfg.pipelined`
/// and `cfg.speculation_depth > 0`; byte-identical outcomes to the
/// barriered engine by construction (module docs), with the
/// speculation ledger as the only addition.
pub(crate) fn optimize_pipelined(
    spec: &KernelSpec,
    cfg: &Config,
    cache: &CompileCache,
    budget: &Arc<WorkerBudget>,
) -> Outcome {
    let quality = match cfg.mode {
        AgentMode::Multi => TestQuality::Representative,
        AgentMode::Single => TestQuality::Unrepresentative,
    };
    let tester = TestingAgent::new(quality, cfg.seed)
        .with_grid_workers(cfg.grid_workers)
        .with_worker_budget(Arc::clone(budget))
        .with_step_limit(cfg.watchdog_steps);
    let profiler = ProfilingAgent::new(cfg.model.clone());
    let mut planner = search::make_planner(cfg);
    let coder = CodingAgent::new(cfg.bug_rate, cfg.seed ^ 0xC0DE);
    let probe = ConcurrencyProbe::new();

    let baseline = (spec.build_baseline)();
    let suite = tester.generate_tests(spec);
    let base_tests = tester.validate_with(spec, &baseline, &suite, Some(cache));
    let base_profile = profiler.profile(&baseline, &suite, None);
    debug_assert!(base_tests.pass, "baseline must pass its own tests");

    let mut records: Vec<RoundRecord> = Vec::new();
    let mut best = baseline.clone();
    let mut best_speedup = 1.0f64;
    let mut candidates_evaluated = 0usize;
    let mut k_per_round: Vec<usize> = Vec::new();
    let mut adaptive_k_events = 0usize;
    let mut cancelled_candidates = 0usize;
    let mut fault_stats = FaultStats::default();
    let mut quarantined_lineages = 0u64;
    let mut ledger = SpecLedger::default();
    let mut best_history: Vec<Move> = Vec::new();
    let mut beam: Vec<BeamState> = vec![BeamState {
        kernel: baseline.clone(),
        tests: base_tests,
        profile: base_profile.clone(),
        speedup: 1.0,
        history: Vec::new(),
        blocked: Vec::new(),
        consec_failures: 0,
    }];

    // ---- artifact store (ROADMAP "crash-consistent store") -----------
    // The pipelined engine journals checkpoints and persists compile
    // metadata + the winning trajectory, but never *replays* a journal:
    // `--resume` dispatches to the barriered engine (byte-identical),
    // so this engine always starts its journal fresh. No eval-skip
    // here either — recorded-verdict reuse stays a barriered-only
    // optimization.
    let store = search::open_store(cfg);
    let runkey = search::run_key(spec, cfg);
    if let Some(s) = &store {
        cache.attach_store(Arc::clone(s));
        s.reset_journal(runkey);
    }
    let mut killed = false;

    let shared = Shared {
        sched: Mutex::new(Sched {
            layers: Vec::new(),
            next_id: 0,
        }),
        queue: TaskQueue::new(),
        done: Condvar::new(),
    };
    let ctx = PipeCtx {
        env: EvalEnv {
            spec,
            cfg,
            tester: &tester,
            profiler: &profiler,
            suite: &suite,
            base_profile: &base_profile,
        },
        cache,
        budget: budget.as_ref(),
        probe: &probe,
        coder: &coder,
        shared: &shared,
    };

    thread::scope(|s| {
        // Pool sizing: enough workers to keep depth+1 overlapped
        // rounds busy, capped (as everywhere) by the process-wide
        // budget — a zero grant degrades to the helping drain in
        // `collect_layer`, the serial engine on the caller.
        let k_per_state = cfg.candidates_per_round.max(1);
        let want = (cfg.beam_width.max(1)
            * k_per_state
            * (cfg.speculation_depth + 1))
            .max(2)
            - 1;
        let lease = budget.try_acquire(want);
        let handles: Vec<_> = (0..lease.granted())
            .map(|_| {
                let ctx = &ctx;
                s.spawn(move || worker_loop(ctx))
            })
            .collect();

        let mut adopted: Option<u64> = None;
        for round in 1..=cfg.rounds {
            // ---- plan canonically, or adopt a committed speculation --
            let (cands, per_state, layer_id, was_speculative) =
                if let Some(id) = adopted.take() {
                    let mut g =
                        shared.sched.lock().expect("scheduler poisoned");
                    let layer = g
                        .layers
                        .iter_mut()
                        .find(|l| l.id == id)
                        .expect("committed layers are never aborted");
                    debug_assert_eq!(layer.round, round);
                    k_per_round.append(&mut layer.k_per_round);
                    adaptive_k_events += layer.adaptive_k_events;
                    fault_stats.add(&layer.gate_stats);
                    planner = layer
                        .planner_after
                        .as_ref()
                        .expect("every layer snapshots its planner")
                        .snapshot();
                    (
                        Arc::clone(&layer.cands),
                        layer.per_state.clone(),
                        id,
                        true,
                    )
                } else {
                    let (c, ps) = search::plan_round(
                        cfg,
                        round,
                        &beam,
                        planner.as_mut(),
                        &coder,
                        &mut fault_stats,
                        &mut k_per_round,
                        &mut adaptive_k_events,
                    );
                    let cands = Arc::new(c);
                    let n = cands.len();
                    let mut g =
                        shared.sched.lock().expect("scheduler poisoned");
                    let id = g.next_id;
                    g.next_id += 1;
                    g.layers.push(Layer {
                        id,
                        round,
                        cands: Arc::clone(&cands),
                        per_state: ps.clone(),
                        beam: beam.clone(),
                        round_best: best_speedup,
                        results: (0..n).map(|_| None).collect(),
                        probes: vec![Vec::new(); n],
                        pending: n,
                        speculative: false,
                        lineage_cancel: Arc::new(AtomicBool::new(false)),
                        cand_tokens: Arc::new(
                            (0..n).map(|_| AtomicBool::new(false)).collect(),
                        ),
                        planner_after: Some(planner.snapshot()),
                        k_per_round: Vec::new(),
                        adaptive_k_events: 0,
                        gate_stats: FaultStats::default(),
                        predicted_selection: Vec::new(),
                        spawned_next: false,
                    });
                    for slot in 0..n {
                        shared.queue.push(TaskKey {
                            round,
                            slot,
                            layer: id,
                        });
                    }
                    // Zero-candidate rounds store nothing, so the
                    // spawn check must run here too.
                    maybe_spawn(&ctx, &mut g);
                    (cands, ps, id, false)
                };
            let round_best = best_speedup;

            // ---- collect this round's evaluations --------------------
            let (raw, probes) = collect_layer(&ctx, layer_id);
            let mut evals: Vec<Option<EvalProduct>> = raw
                .into_iter()
                .enumerate()
                .map(|(i, r)| match r {
                    Ok(v) => v,
                    Err(msg) => Some(search::panicked_product(
                        &profiler,
                        &cands[i].kernel,
                        &suite,
                        Some(&base_profile),
                        &msg,
                    )),
                })
                .collect();

            // ---- commit replay: restore the cache traffic ------------
            // A committed round validated cache-free; replay the exact
            // compile-cache probes (per attempt key, per candidate, in
            // index order) the cache-carrying barriered evaluations
            // would have made. Unneeded at `round_budget > 0`, where
            // the barriered engine is cache-free too.
            if was_speculative && cfg.round_budget == 0 {
                for (i, keys) in probes.iter().enumerate() {
                    for akey in keys {
                        tester
                            .with_fault_context(cfg.fault, *akey)
                            .replay_cache_probes(
                                &cands[i].kernel,
                                &suite,
                                cache,
                            );
                    }
                }
            }

            // ---- settle (the shared seam) ----------------------------
            let mut tally = RoundTally {
                records: &mut records,
                best: &mut best,
                best_speedup: &mut best_speedup,
                best_history: &mut best_history,
                candidates_evaluated: &mut candidates_evaluated,
                cancelled_candidates: &mut cancelled_candidates,
                fault_stats: &mut fault_stats,
                quarantined_lineages: &mut quarantined_lineages,
            };
            let (next_beam, selection) = search::settle_round(
                &ctx.env,
                round,
                round_best,
                beam,
                cands.as_slice(),
                &per_state,
                &mut evals,
                &mut tally,
            );
            beam = next_beam;

            // ---- journal checkpoint ----------------------------------
            // The settled round (normalized by `settle_round`: `Some`
            // means canonically kept) lands on disk with its per-slot
            // probe ledger before the next round is adopted; a killed
            // pipelined run resumes on the barriered engine, replaying
            // these frames byte-identically. The hidden kill knob
            // crashes right after the checkpoint.
            if let Some(s) = &store {
                let slots: Vec<Option<EvalSlot>> = evals
                    .iter()
                    .enumerate()
                    .map(|(i, e)| {
                        e.as_ref().map(|p| EvalSlot {
                            tests: p.tests.clone(),
                            stats: p.stats,
                            probe_keys: probes
                                .get(i)
                                .cloned()
                                .unwrap_or_default(),
                        })
                    })
                    .collect();
                s.append_round(runkey, round, &slots);
                if cfg.kill_after_round > 0 && round == cfg.kill_after_round {
                    killed = true;
                    let mut g =
                        shared.sched.lock().expect("scheduler poisoned");
                    abort_chain(&mut g);
                    drop(g);
                    break;
                }
            }

            // ---- judge the immediate-next speculation ----------------
            let mut g = shared.sched.lock().expect("scheduler poisoned");
            let pos = g
                .layers
                .iter()
                .position(|l| l.id == layer_id)
                .expect("the settled layer is still registered");
            g.layers.remove(pos);
            if let Some(next) = g.layers.first() {
                debug_assert!(next.speculative);
                debug_assert_eq!(next.round, round + 1);
                ledger.speculated += 1;
                if next.predicted_selection == selection
                    && next.round_best.to_bits() == best_speedup.to_bits()
                {
                    ledger.committed += 1;
                    adopted = Some(next.id);
                } else {
                    ledger.aborted += 1;
                    abort_chain(&mut g);
                }
            }
            // A settled (or aborted) round frees depth headroom.
            maybe_spawn(&ctx, &mut g);
            drop(g);
        }

        shared.queue.close();
        for h in handles {
            h.join().expect("pipelined pool worker panicked");
        }
        drop(lease);
    });

    // ---- warm start: replay the stored best trajectory (shared with
    // the barriered engine; skipped when the kill knob crashed us).
    if let Some(s) = &store {
        if !killed {
            search::warm_finish(
                s,
                spec,
                cfg,
                &tester,
                &profiler,
                cache,
                &suite,
                &baseline,
                &base_profile,
                &mut records,
                &mut best,
                &mut best_speedup,
                &mut best_history,
            );
        }
    }
    let store_ledger = search::harvest_store(&store, 0);

    search::finish_outcome(
        spec,
        cfg,
        records,
        baseline,
        best,
        cache,
        budget,
        SearchTelemetry {
            candidates_evaluated,
            peak_concurrent_evals: probe.peak(),
            k_per_round,
            adaptive_k_rounds: adaptive_k_events,
            cancelled_candidates,
            fault_stats,
            quarantined_lineages,
            speculation: ledger,
            store: store_ledger,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimize;
    use crate::kernels;

    fn pipe_cfg(depth: usize) -> Config {
        Config {
            pipelined: true,
            speculation_depth: depth,
            candidates_per_round: 3,
            ..Config::multi_agent()
        }
    }

    #[test]
    fn pipelined_matches_barriered_on_every_kernel() {
        for spec in kernels::all_specs() {
            let p = optimize(&spec, &pipe_cfg(2));
            let b = optimize(
                &spec,
                &Config {
                    pipelined: false,
                    ..pipe_cfg(2)
                },
            );
            assert_eq!(p.records, b.records, "{}", spec.paper_name);
            assert_eq!(p.best, b.best, "{}", spec.paper_name);
            assert_eq!(
                p.final_speedup.to_bits(),
                b.final_speedup.to_bits(),
                "{}",
                spec.paper_name
            );
            assert_eq!(p.cache_hits, b.cache_hits, "{}", spec.paper_name);
            assert_eq!(p.cache_misses, b.cache_misses, "{}", spec.paper_name);
            assert_eq!(p.candidates_evaluated, b.candidates_evaluated);
            assert_eq!(p.k_per_round, b.k_per_round);
            assert_eq!(
                b.speculated_lineages, 0,
                "the barriered engine never speculates across rounds"
            );
        }
    }

    #[test]
    fn depth_zero_runs_the_legacy_engine_with_a_zero_ledger() {
        let cfg = pipe_cfg(0);
        let out = optimize(&kernels::silu::spec(), &cfg);
        assert!(out.final_correct);
        assert_eq!(out.speculated_lineages, 0);
        assert_eq!(out.committed_lineages, 0);
        assert_eq!(out.aborted_lineages, 0);
    }

    #[test]
    fn speculation_ledger_is_consistent_and_fires_on_a_quiet_run() {
        let cfg = Config {
            bug_rate: 0.0,
            temperature: 0.0,
            ..pipe_cfg(1)
        };
        let out = optimize(&kernels::merge::spec(), &cfg);
        assert!(out.final_correct);
        assert_eq!(
            out.speculated_lineages,
            out.committed_lineages + out.aborted_lineages,
            "every speculated lineage is judged exactly once"
        );
        assert!(
            out.speculated_lineages > 0,
            "a quiet pipelined run must speculate across the barrier"
        );
    }

    #[test]
    fn pipelined_preset_matches_its_barriered_twin() {
        let preset = Config::multi_agent_pipelined();
        let barriered = Config {
            pipelined: false,
            ..preset.clone()
        };
        for spec in kernels::all_specs() {
            let p = optimize(&spec, &preset);
            let b = optimize(&spec, &barriered);
            assert_eq!(p.records, b.records, "{}", spec.paper_name);
            assert_eq!(p.best, b.best, "{}", spec.paper_name);
            assert_eq!(
                p.final_speedup.to_bits(),
                b.final_speedup.to_bits(),
                "{}",
                spec.paper_name
            );
        }
    }

    #[test]
    fn pipelined_is_deterministic_across_worker_budgets() {
        let spec = kernels::rmsnorm::spec();
        let cfg = pipe_cfg(2);
        let a = optimize(&spec, &cfg);
        for wb in [1usize, 2, 7] {
            let b = optimize(
                &spec,
                &Config {
                    worker_budget: wb,
                    ..cfg.clone()
                },
            );
            assert_eq!(a.records, b.records, "wb={wb}");
            assert_eq!(a.best, b.best, "wb={wb}");
            assert_eq!(
                a.final_speedup.to_bits(),
                b.final_speedup.to_bits(),
                "wb={wb}"
            );
            assert_eq!(a.speculated_lineages, b.speculated_lineages, "wb={wb}");
            assert_eq!(a.committed_lineages, b.committed_lineages, "wb={wb}");
            assert_eq!(a.aborted_lineages, b.aborted_lineages, "wb={wb}");
            assert_eq!(a.cache_hits, b.cache_hits, "wb={wb}");
            assert_eq!(a.cache_misses, b.cache_misses, "wb={wb}");
        }
    }
}
