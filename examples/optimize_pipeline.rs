//! End-to-end driver (DESIGN.md §6): the full system on a real workload.
//!
//! 1. Run the multi-agent optimization (Algorithm 1, R = 5) on all three
//!    SGLang kernels concurrently — the paper's headline experiment.
//! 2. Post-process every winner: re-validate against the SGLang-semantics
//!    oracle AND cross-check the oracle itself against the AOT Pallas
//!    artifacts executed over PJRT (the two independent ground truths must
//!    agree before we trust either).
//! 3. Reintegrate: serve batched decode-layer requests through the PJRT
//!    pipeline with baseline vs optimized kernel artifacts and report
//!    latency/throughput — the drop-in-replacement claim of §3.2.
//!
//! ```bash
//! make artifacts && cargo run --release --example optimize_pipeline
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use astra::coordinator::{optimize_all_parallel, Config};
use astra::pipeline::DecodePipeline;
use astra::runtime::{default_artifacts_dir, Engine};
use astra::util::Prng;
use astra::{kernels, report};

fn main() -> anyhow::Result<()> {
    println!("== Astra end-to-end: optimize -> validate -> serve ==\n");

    // ---- 1. multi-agent optimization over all kernels -------------------
    let cfg = Config::multi_agent();
    let t0 = std::time::Instant::now();
    let outcomes = optimize_all_parallel(&cfg);
    println!(
        "optimized {} kernels in {:.2}s (one coordinator thread each)\n",
        outcomes.len(),
        t0.elapsed().as_secs_f64()
    );
    println!("{}", report::table2(&outcomes));

    // ---- 2. post-processing validation ----------------------------------
    let dir = default_artifacts_dir()?;
    let mut eng = Engine::from_dir(&dir)?;
    println!("PJRT platform: {}\n", eng.platform());

    for o in &outcomes {
        assert!(o.final_correct, "{} failed oracle validation", o.kernel_name);
    }
    // Cross-check the Rust oracle against the Pallas artifacts (silu).
    let mut rng = Prng::seed(99);
    let xg = rng.normal_vec(8 * 512, 1.5);
    let pjrt_out = eng.execute("silu_opt_oracle", &[xg.clone()])?;
    let rust_out = kernels::reference::silu_and_mul(8, 256, &xg);
    let max_rel = pjrt_out[0]
        .iter()
        .zip(&rust_out)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1.0))
        .fold(0f32, f32::max);
    println!(
        "oracle cross-check (Rust reference vs PJRT Pallas): max rel err {max_rel:.2e}"
    );
    assert!(max_rel < 2e-2);

    // ---- 3a. per-kernel artifact timings on the CPU PJRT client ---------
    // (interpret-mode Pallas on CPU is a *structural* check, not a TPU/GPU
    // performance proxy — the modeled GPU numbers are Table 2 above.)
    println!("per-kernel serve artifacts on CPU PJRT (10-call mean):");
    let mut gen = Prng::seed(5);
    for (base, opt, arities) in [
        ("merge_base_serve", "merge_opt_serve", vec![32 * 8 * 64, 32 * 8, 32 * 8 * 64, 32 * 8]),
        ("rmsnorm_base_serve", "rmsnorm_opt_serve", vec![32 * 512, 32 * 512, 512]),
        ("silu_base_serve", "silu_opt_serve", vec![32 * 2048]),
    ] {
        let inputs: Vec<Vec<f32>> =
            arities.iter().map(|n| gen.normal_vec(*n, 1.0)).collect();
        let mut time = |name: &str| -> anyhow::Result<f64> {
            eng.prepare(name)?;
            for _ in 0..3 {
                eng.execute(name, &inputs)?;
            }
            let t0 = std::time::Instant::now();
            for _ in 0..10 {
                eng.execute(name, &inputs)?;
            }
            Ok(t0.elapsed().as_secs_f64() * 1e5)
        };
        let tb = time(base)?;
        let to = time(opt)?;
        println!("  {base:<22} {tb:>7.0} us  |  {opt:<22} {to:>7.0} us");
    }

    // ---- 3b. serve through the decode-layer pipeline ---------------------
    println!("\nserving 100 batched decode steps per variant (CPU PJRT; \nlatency dominated by the f32 matmuls, not the kernels under study):");
    let mut results = Vec::new();
    for variant in ["baseline", "optimized"] {
        let eng = Engine::from_dir(&dir)?;
        let mut pipe = DecodePipeline::new(eng, variant, 7)?;
        let stats = pipe.serve(100, 10, 3)?;
        println!(
            "  {variant:<10} batch={} mean={:>7.0}us p50={:>7.0}us p95={:>7.0}us \
             p99={:>7.0}us throughput={:>8.0} tok/s",
            stats.batch, stats.mean_us, stats.p50_us, stats.p95_us, stats.p99_us,
            stats.tokens_per_s
        );
        results.push(stats);
    }
    let ratio = results[1].tokens_per_s / results[0].tokens_per_s;
    println!(
        "\npipeline throughput optimized/baseline = {ratio:.2}x on CPU PJRT \
         \n(structural drop-in check only — interpret-mode Pallas wall-clock is \
         \nnot a GPU proxy; the paper-comparable speedups are Table 2 above)"
    );

    println!("\nE2E complete: all layers compose.");
    Ok(())
}
