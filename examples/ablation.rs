//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. Per-move contribution: each transform applied alone vs the full
//!    composition (which Figure 2-5 strategy buys what).
//! 2. Test-suite quality: the §5.2 bias mechanism isolated — the same
//!    planner with representative vs unrepresentative profiling shapes.
//! 3. Round budget: speedup as a function of R (the paper fixes R = 5).
//! 4. Failure injection: the correctness gate under rising coding-agent
//!    bug rates (candidates must never ship incorrect).
//! 5. Speculative search: final speedup, candidates evaluated and wall
//!    clock as the beam widens from the paper's greedy loop (B=1, K=1)
//!    to concurrent multi-candidate rounds (EXPERIMENTS.md §Beam).
//! 6. Adaptive speculation: priority-gap-driven K plus round
//!    cancellation vs the matching static beam row
//!    (EXPERIMENTS.md §Adaptive-K).
//! 7. Scenario specialization: one search per serving scenario
//!    (decode-small-batch vs prefill-large-batch dim sets) vs the single
//!    global winner, cross-evaluated on each scenario's shapes
//!    (EXPERIMENTS.md §Per-scenario).
//!
//! ```bash
//! cargo run --release --example ablation
//! ```

use std::sync::Arc;

use astra::coordinator::{optimize, optimize_scenarios, AgentMode, Config};
use astra::interp::{CompileCache, WorkerBudget};
use astra::kernels;
use astra::sim::{self, GpuModel};
use astra::transforms::{self, Move};

fn main() {
    let model = GpuModel::h100();

    // ---- 1. per-move contribution ---------------------------------------
    println!("== Ablation 1: single-move speedups (geomean over Table-4 shapes) ==");
    println!(
        "{:<24} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "kernel", "hoist", "vector", "shuffle", "fastmath", "unroll8", "ALL"
    );
    for spec in kernels::all_specs() {
        let base = (spec.build_baseline)();
        let shapes = (spec.representative_shapes)();
        let b = sim::profile_shapes(&model, &base, &shapes);
        let single = |mv: Move| -> String {
            match transforms::apply(&base, mv) {
                Ok(k) => {
                    let o = sim::profile_shapes(&model, &k, &shapes);
                    format!("{:.2}x", sim::geomean_speedup(&b, &o))
                }
                Err(_) => "n/a".to_string(),
            }
        };
        let all = {
            let k = transforms::optimized_reference(&base);
            let o = sim::profile_shapes(&model, &k, &shapes);
            format!("{:.2}x", sim::geomean_speedup(&b, &o))
        };
        println!(
            "{:<24} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
            spec.paper_name,
            single(Move::Hoist),
            single(Move::Vectorize),
            single(Move::WarpShuffle),
            single(Move::FastMath),
            single(Move::Unroll(8)),
            all
        );
    }

    // ---- 2. test-suite quality (the §5.2 mechanism, isolated) -----------
    println!("\n== Ablation 2: profiling-shape quality (same planner) ==");
    for (label, mode, temp) in [
        ("multi-agent + representative", AgentMode::Multi, 0.0f32),
        ("single-agent + tiny shapes", AgentMode::Single, 0.0),
    ] {
        print!("{label:<32}");
        for spec in kernels::all_specs() {
            let cfg = Config {
                mode,
                temperature: temp,
                bug_rate: 0.0,
                ..Config::multi_agent()
            };
            let o = optimize(&spec, &cfg);
            print!("  K{} {:.2}x", spec.index, o.final_speedup);
        }
        println!();
    }

    // ---- 3. round budget --------------------------------------------------
    println!("\n== Ablation 3: speedup vs optimization rounds R (kernel 1) ==");
    let spec = kernels::merge::spec();
    for rounds in [1usize, 2, 3, 5, 8] {
        let cfg = Config {
            rounds,
            bug_rate: 0.0,
            temperature: 0.0,
            ..Config::multi_agent()
        };
        let o = optimize(&spec, &cfg);
        println!("  R = {rounds}: {:.2}x", o.final_speedup);
    }

    // ---- 4. failure injection ---------------------------------------------
    println!("\n== Ablation 4: correctness gate under coding-agent bug rates ==");
    for bug_rate in [0.0f32, 0.25, 0.5, 0.9] {
        let cfg = Config {
            bug_rate,
            ..Config::multi_agent()
        };
        let mut all_correct = true;
        let mut worst: f64 = f64::INFINITY;
        for spec in kernels::all_specs() {
            let o = optimize(&spec, &cfg);
            all_correct &= o.final_correct;
            worst = worst.min(o.final_speedup);
        }
        println!(
            "  bug_rate {bug_rate:.2}: shipped kernels correct = {all_correct}, \
             worst speedup {worst:.2}x"
        );
    }

    // ---- 5. speculative beam search ---------------------------------------
    println!("\n== Ablation 5: beam width B x candidates K (multi-agent) ==");
    for (b, k) in [(1usize, 1usize), (1, 3), (2, 2), (2, 3), (3, 3)] {
        print!("  B={b} K={k}:");
        for spec in kernels::all_specs() {
            let cfg = Config {
                beam_width: b,
                candidates_per_round: k,
                bug_rate: 0.0,
                temperature: 0.0,
                ..Config::multi_agent()
            };
            let t0 = std::time::Instant::now();
            let o = optimize(&spec, &cfg);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            print!(
                "  K{} {:.2}x ({} cands, {:.0} ms)",
                spec.index, o.final_speedup, o.candidates_evaluated, ms
            );
        }
        println!();
    }

    // ---- 6. adaptive speculation scheduler -------------------------------
    // EXPERIMENTS.md §Adaptive-K: the static B x K grid above is the
    // baseline; the adaptive rows spend K only where the planner's
    // priority gap says speculation pays, and cancel a round's
    // stragglers once `round_budget` candidates evaluated with one
    // strictly better. Compare speedup / candidates evaluated / wall
    // clock against the matching static row (B=2 K=3).
    println!(
        "\n== Ablation 6: adaptive K + round cancellation vs static B=2 K=3 =="
    );
    let static_beam = Config {
        bug_rate: 0.0,
        temperature: 0.0,
        ..Config::multi_agent_beam()
    };
    let adaptive = Config {
        bug_rate: 0.0,
        temperature: 0.0,
        ..Config::multi_agent_adaptive()
    };
    let mut adaptive_nocancel = adaptive.clone();
    adaptive_nocancel.round_budget = 0;
    for (label, cfg) in [
        ("static   B=2 K=3         ", &static_beam),
        ("adaptive  K<=3 (no cancel)", &adaptive_nocancel),
        ("adaptive  K<=3 + budget 3 ", &adaptive),
    ] {
        print!("  {label}:");
        for spec in kernels::all_specs() {
            let t0 = std::time::Instant::now();
            let o = optimize(&spec, cfg);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            print!(
                "  K{} {:.2}x ({} cands, {} shrunk, {} cancelled, {:.0} ms)",
                spec.index,
                o.final_speedup,
                o.candidates_evaluated,
                o.adaptive_k_rounds,
                o.cancelled_candidates,
                ms
            );
        }
        println!();
    }

    // ---- 7. per-scenario winners vs one global winner ---------------------
    // EXPERIMENTS.md §Per-scenario: does searching per serving scenario
    // (decode vs prefill dim sets from the catalog) beat shipping the
    // one global winner everywhere? For each bucket we report the
    // specialized search's speedup on its own shapes next to the global
    // winner cross-evaluated on those same shapes; `!=` marks buckets
    // whose specialized composition differs from the global one. With
    // scenario_split off the table collapses to a single "global"
    // bucket — byte-identical to the legacy engine (tests/dispatch.rs).
    println!("\n== Ablation 7: per-scenario winners vs one global winner ==");
    println!(
        "  {:<24} {:<9} {:>9} {:>10} {:>8}",
        "kernel", "scenario", "special", "global@sc", "differs"
    );
    let cache = Arc::new(CompileCache::with_default_capacity());
    let budget = Arc::new(WorkerBudget::from_config(0));
    let global_cfg = Config {
        bug_rate: 0.0,
        temperature: 0.0,
        ..Config::multi_agent()
    };
    let split_cfg = Config {
        scenario_split: true,
        dispatch: true,
        ..global_cfg.clone()
    };
    for spec in kernels::all_specs() {
        let global_run = optimize_scenarios(&spec, &global_cfg, &cache, &budget);
        let global = &global_run[0];
        let per_scenario = optimize_scenarios(&spec, &split_cfg, &cache, &budget);
        let base = (spec.build_baseline)();
        let buckets = (spec.scenarios)();
        for s in &per_scenario {
            // The global winner, re-profiled on this bucket's dim set.
            let shapes = &buckets[s.scenario_index].shapes;
            let b = sim::profile_shapes(&model, &base, shapes);
            let g = sim::profile_shapes(&model, &global.outcome.best, shapes);
            let differs = astra::interp::kernel_hash(&s.outcome.best)
                != astra::interp::kernel_hash(&global.outcome.best);
            println!(
                "  {:<24} {:<9} {:>8.2}x {:>9.2}x {:>8}",
                spec.paper_name,
                s.scenario,
                s.outcome.final_speedup,
                sim::geomean_speedup(&b, &g),
                if differs { "yes" } else { "no" }
            );
        }
    }
}
