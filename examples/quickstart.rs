//! Quickstart: optimize one SGLang kernel with the multi-agent loop and
//! inspect what the agents did.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use astra::coordinator::{optimize, Config};
use astra::{kernels, report};

fn main() {
    // Pick Kernel 3 (silu_and_mul) — the paper's Figures 4-5 case study.
    let spec = kernels::silu::spec();
    let cfg = Config::multi_agent();

    println!("== Astra quickstart: {} ==\n", spec.paper_name);
    let outcome = optimize(&spec, &cfg);

    // Round-by-round log (Algorithm 1's Log).
    println!("{}", report::trace(&outcome));

    // The before/after source (Figures 4-5).
    println!("{}", report::case_study(&spec));

    println!(
        "Result: {:.2}x geomean speedup on the paper's Table-4 shapes \
         (paper: 1.46x), correct = {}",
        outcome.final_speedup, outcome.final_correct
    );
}
