//! Shape-sensitivity study (paper §6.1 / Table 4), extended.
//!
//! Reproduces Table 4 on the paper's 12 shapes, then sweeps a wider grid
//! to check the §6.1 claim that Astra's optimizations generalize across
//! shapes rather than being tuned to one (speedup stays >= ~1 everywhere
//! and varies smoothly).
//!
//! ```bash
//! cargo run --release --example shape_sweep
//! ```

use astra::coordinator::{optimize_all_parallel, Config};
use astra::kernels::{self, dims_of};
use astra::sim::{self, GpuModel};
use astra::transforms;
use astra::report;

fn main() {
    let cfg = Config::multi_agent();
    let outcomes = optimize_all_parallel(&cfg);
    println!("{}", report::table4(&outcomes));

    // Extended sweep on the hand-verified optimized composition, so the
    // generality claim is about the *transforms*, not one agent run.
    println!("Extended sweep (optimized_reference, beyond Table 4):");
    let model = GpuModel::h100();

    println!("\nkernel 2 (fused_add_rmsnorm), batch x hidden grid:");
    let base = kernels::rmsnorm::build_baseline();
    let opt = transforms::optimized_reference(&base);
    print!("{:>8}", "B\\D");
    for d in [2048i64, 4096, 8192, 14336] {
        print!("{d:>9}");
    }
    println!();
    for b in [32i64, 128, 512, 2048] {
        print!("{b:>8}");
        for d in [2048i64, 4096, 8192, 14336] {
            let dims = dims_of(&[("B", b), ("D", d)]);
            let tb = sim::simulate(&model, &base, &dims).total_us;
            let to = sim::simulate(&model, &opt, &dims).total_us;
            print!("{:>8.2}x", tb / to);
        }
        println!();
    }

    println!("\nkernel 3 (silu_and_mul), batch x intermediate grid:");
    let base = kernels::silu::build_baseline();
    let opt = transforms::optimized_reference(&base);
    print!("{:>8}", "B\\D");
    for d in [2048i64, 4096, 8192, 12288] {
        print!("{d:>9}");
    }
    println!();
    for b in [8i64, 16, 64, 256] {
        print!("{b:>8}");
        for d in [2048i64, 4096, 8192, 12288] {
            let dims = dims_of(&[("B", b), ("D", d)]);
            let tb = sim::simulate(&model, &base, &dims).total_us;
            let to = sim::simulate(&model, &opt, &dims).total_us;
            print!("{:>8.2}x", tb / to);
        }
        println!();
    }

    println!(
        "\nNo shape-specific tuning was performed (§6.1): the same \
         transformed kernel is measured at every shape."
    );
}
