//! Shape-sensitivity study (paper §6.1 / Table 4), extended.
//!
//! Reproduces Table 4 on the paper's 12 shapes, then sweeps a wider grid
//! to check the §6.1 claim that Astra's optimizations generalize across
//! shapes rather than being tuned to one (speedup stays >= ~1 everywhere
//! and varies smoothly), and finishes with the §Grid-parallel
//! worker-count sweep (EXPERIMENTS.md): the block-parallel interpreter
//! on each kernel's largest correctness shape at 1/2/4/8 workers.
//!
//! ```bash
//! cargo run --release --example shape_sweep
//! ```

use astra::coordinator::{optimize_all_parallel, Config};
use astra::interp::{self, RunOpts};
use astra::kernels::{self, dims_of};
use astra::sim::{self, GpuModel};
use astra::transforms;
use astra::report;

fn main() {
    let cfg = Config::multi_agent();
    let outcomes = optimize_all_parallel(&cfg);
    println!("{}", report::table4(&outcomes));

    // Extended sweep on the hand-verified optimized composition, so the
    // generality claim is about the *transforms*, not one agent run.
    println!("Extended sweep (optimized_reference, beyond Table 4):");
    let model = GpuModel::h100();

    println!("\nkernel 2 (fused_add_rmsnorm), batch x hidden grid:");
    let base = kernels::rmsnorm::build_baseline();
    let opt = transforms::optimized_reference(&base);
    print!("{:>8}", "B\\D");
    for d in [2048i64, 4096, 8192, 14336] {
        print!("{d:>9}");
    }
    println!();
    for b in [32i64, 128, 512, 2048] {
        print!("{b:>8}");
        for d in [2048i64, 4096, 8192, 14336] {
            let dims = dims_of(&[("B", b), ("D", d)]);
            let tb = sim::simulate(&model, &base, &dims).total_us;
            let to = sim::simulate(&model, &opt, &dims).total_us;
            print!("{:>8.2}x", tb / to);
        }
        println!();
    }

    println!("\nkernel 3 (silu_and_mul), batch x intermediate grid:");
    let base = kernels::silu::build_baseline();
    let opt = transforms::optimized_reference(&base);
    print!("{:>8}", "B\\D");
    for d in [2048i64, 4096, 8192, 12288] {
        print!("{d:>9}");
    }
    println!();
    for b in [8i64, 16, 64, 256] {
        print!("{b:>8}");
        for d in [2048i64, 4096, 8192, 12288] {
            let dims = dims_of(&[("B", b), ("D", d)]);
            let tb = sim::simulate(&model, &base, &dims).total_us;
            let to = sim::simulate(&model, &opt, &dims).total_us;
            print!("{:>8.2}x", tb / to);
        }
        println!();
    }

    println!(
        "\nNo shape-specific tuning was performed (§6.1): the same \
         transformed kernel is measured at every shape."
    );

    // §Grid-parallel / §Zero-copy protocol (EXPERIMENTS.md):
    // block-parallel interpreter wall clock vs worker count on each
    // kernel's largest correctness shape, on both grid engines —
    // copy-and-merge (`w=N` columns, forced) and zero-copy sliced
    // (`zc=N` columns, the default for the whole catalog, whose
    // kernels all carry a slice plan). grid_workers = 1 is the serial
    // engine byte-for-byte; the differential wall pins every count and
    // both engines identical, so this sweep is purely wall clock.
    println!(
        "\nGrid-parallel interpreter sweep (largest correctness shape, \
         5-run mean; w = copy-merge, zc = zero-copy):"
    );
    for spec in kernels::all_specs() {
        let k = (spec.build_baseline)();
        let dims = &spec.largest_test_shape(&k);
        let inputs = (spec.gen_inputs)(dims, 7);
        let refs: Vec<(&str, Vec<f32>)> = inputs
            .iter()
            .map(|(n, v)| (n.as_str(), v.clone()))
            .collect();
        let prog = interp::compile(&k, dims).expect("baseline compiles");
        let time_at = |workers: usize, allow_zero_copy: bool| {
            let t0 = std::time::Instant::now();
            for _ in 0..5 {
                let mut env = interp::ExecEnv::for_kernel(&k, dims);
                for (name, data) in &refs {
                    env.set(name, data.clone());
                }
                interp::run_compiled_with_opts(
                    &prog,
                    &mut env,
                    RunOpts {
                        grid_workers: workers,
                        allow_zero_copy,
                        ..RunOpts::default()
                    },
                )
                .unwrap();
            }
            t0.elapsed().as_secs_f64() * 1e3 / 5.0
        };
        print!("{:<24}", spec.paper_name);
        for workers in [1usize, 2, 4, 8] {
            print!("  w={workers}: {:>7.2}ms", time_at(workers, false));
        }
        for workers in [4usize, 8] {
            print!("  zc={workers}: {:>7.2}ms", time_at(workers, true));
        }
        if !prog.sliceable() {
            print!("  [zc falls back: not sliceable]");
        }
        println!();
    }
}
