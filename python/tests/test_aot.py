"""AOT path: HLO-text lowering round-trips and the manifest is coherent."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels import silu


def test_entries_enumerate():
    names = [e[0] for e in aot.entries()]
    # 3 kernels x 2 variants x 2 roles + 2 decode layers
    assert len(names) == 14
    assert len(set(names)) == len(names)
    for k in ("merge", "rmsnorm", "silu", "decode_layer"):
        assert any(n.startswith(k) for n in names)


def test_hlo_text_lowering_roundtrip():
    """Lowered HLO text parses back through the XLA text parser.

    (Numerical execution of the text artifacts is covered by the Rust
    integration tests over the PJRT runtime — that is the consumer.)
    """
    lowered = silu.optimized.lower(jax.ShapeDtypeStruct((8, 512), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    from jax._src.lib import xla_client as xc

    mod = xc._xla.hlo_module_from_text(text)
    reparsed = mod.to_string()
    assert "ENTRY" in reparsed
    # Entry computation signature: one f32[8,512] param, tuple result.
    assert "f32[8,512]" in reparsed
    assert "f32[8,256]" in reparsed


def test_aot_writes_manifest(tmp_path):
    """--only silu_opt_oracle produces a file + coherent manifest entry."""
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--outdir", str(tmp_path), "--only", "silu_opt_oracle"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert len(manifest) == 1
    ent = manifest[0]
    assert ent["kernel"] == "silu_and_mul"
    assert ent["variant"] == "optimized"
    assert os.path.exists(tmp_path / ent["file"])
    assert ent["inputs"][0]["shape"] == [8, 512]
    assert ent["outputs"][0]["shape"] == [8, 256]
    text = open(tmp_path / ent["file"]).read()
    assert "ENTRY" in text
