"""Kernel 2 (fused_add_rmsnorm): Pallas variants vs pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, rmsnorm

TOL = dict(rtol=1e-5, atol=1e-5)


def _inputs(rng, b, d):
    x = rng.standard_normal((b, d), dtype=np.float32)
    r = rng.standard_normal((b, d), dtype=np.float32)
    w = (1.0 + 0.1 * rng.standard_normal(d)).astype(np.float32)
    return x, r, w


@pytest.mark.parametrize("variant", [rmsnorm.baseline, rmsnorm.optimized])
def test_matches_oracle(rng, variant):
    x, r, w = _inputs(rng, 8, 256)
    y, rn = variant(x, r, w)
    y_ref, rn_ref = ref.fused_add_rmsnorm(x, r, w)
    np.testing.assert_allclose(y, y_ref, **TOL)
    np.testing.assert_allclose(rn, rn_ref, **TOL)


def test_variants_agree(rng):
    x, r, w = _inputs(rng, 16, 512)
    yb, rb = rmsnorm.baseline(x, r, w)
    yo, ro = rmsnorm.optimized(x, r, w)
    np.testing.assert_allclose(yb, yo, **TOL)
    np.testing.assert_allclose(rb, ro, **TOL)


def test_residual_is_sum(rng):
    x, r, w = _inputs(rng, 8, 256)
    _, rn = rmsnorm.optimized(x, r, w)
    np.testing.assert_allclose(rn, x + r, **TOL)


def test_unit_norm_rows(rng):
    """Each output row of y/w has RMS 1 (up to eps)."""
    x, r, w = _inputs(rng, 8, 256)
    y, _ = rmsnorm.optimized(x, r, w)
    z = np.asarray(y) / w[None, :]
    rms = np.sqrt(np.mean(z * z, axis=1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_zero_input_finite():
    x = np.zeros((8, 256), np.float32)
    w = np.ones(256, np.float32)
    y, rn = rmsnorm.optimized(x, x, w)
    assert np.all(np.isfinite(np.asarray(y)))
    np.testing.assert_allclose(rn, 0.0)


@settings(max_examples=8, deadline=None)
@given(
    b=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([128, 256, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matches_oracle(b, d, seed):
    rng = np.random.default_rng(seed)
    x, r, w = _inputs(rng, b, d)
    for variant in (rmsnorm.baseline, rmsnorm.optimized):
        y, rn = variant(x, r, w, block_rows=4)
        y_ref, rn_ref = ref.fused_add_rmsnorm(x, r, w)
        np.testing.assert_allclose(y, y_ref, **TOL)
        np.testing.assert_allclose(rn, rn_ref, **TOL)


def test_block_rows_invariance(rng):
    x, r, w = _inputs(rng, 16, 256)
    y1, _ = rmsnorm.optimized(x, r, w, block_rows=2)
    y2, _ = rmsnorm.optimized(x, r, w, block_rows=16)
    np.testing.assert_allclose(y1, y2, **TOL)
