"""Kernel 1 (merge_attn_states_lse): Pallas variants vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import merge_attn, ref

TOL = dict(rtol=1e-5, atol=1e-5)


def _inputs(rng, s, h, d, scale=3.0):
    v_a = rng.standard_normal((s, h, d), dtype=np.float32)
    v_b = rng.standard_normal((s, h, d), dtype=np.float32)
    s_a = (scale * rng.standard_normal((s, h))).astype(np.float32)
    s_b = (scale * rng.standard_normal((s, h))).astype(np.float32)
    return v_a, s_a, v_b, s_b


@pytest.mark.parametrize("variant", [merge_attn.baseline, merge_attn.optimized])
def test_matches_oracle(rng, variant):
    args = _inputs(rng, 8, 4, 64)
    v, s = variant(*args)
    v_ref, s_ref = ref.merge_attn_states_lse(*args)
    np.testing.assert_allclose(v, v_ref, **TOL)
    np.testing.assert_allclose(s, s_ref, **TOL)


def test_variants_agree(rng):
    args = _inputs(rng, 16, 8, 128)
    vb, sb = merge_attn.baseline(*args)
    vo, so = merge_attn.optimized(*args)
    np.testing.assert_allclose(vb, vo, **TOL)
    np.testing.assert_allclose(sb, so, **TOL)


def test_extreme_scores_stable(rng):
    """Large score gaps must not overflow (log-sum-exp trick)."""
    v_a, s_a, v_b, s_b = _inputs(rng, 4, 2, 32)
    s_a = s_a + 80.0
    s_b = s_b - 80.0
    for variant in (merge_attn.baseline, merge_attn.optimized):
        v, s = variant(v_a, s_a, v_b, s_b)
        assert np.all(np.isfinite(np.asarray(v)))
        assert np.all(np.isfinite(np.asarray(s)))
        # With s_a >> s_b the merge must collapse to state a.
        np.testing.assert_allclose(v, v_a, rtol=1e-4, atol=1e-4)


def test_equal_scores_average(rng):
    v_a, s_a, v_b, _ = _inputs(rng, 4, 2, 32)
    v, s = merge_attn.optimized(v_a, s_a, v_b, s_a)
    np.testing.assert_allclose(v, 0.5 * (v_a + v_b), **TOL)
    np.testing.assert_allclose(s, s_a + np.log(2.0), rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    s=st.sampled_from([4, 8, 16]),
    h=st.sampled_from([1, 2, 8]),
    d=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matches_oracle(s, h, d, seed):
    rng = np.random.default_rng(seed)
    args = _inputs(rng, s, h, d)
    v, sc = merge_attn.optimized(*args, block_rows=4)
    v_ref, s_ref = ref.merge_attn_states_lse(*args)
    np.testing.assert_allclose(v, v_ref, **TOL)
    np.testing.assert_allclose(sc, s_ref, **TOL)


def test_block_rows_invariance(rng):
    """Result must not depend on the BlockSpec row blocking."""
    args = _inputs(rng, 16, 4, 64)
    v1, s1 = merge_attn.optimized(*args, block_rows=2)
    v2, s2 = merge_attn.optimized(*args, block_rows=16)
    np.testing.assert_allclose(v1, v2, **TOL)
    np.testing.assert_allclose(s1, s2, **TOL)


def test_output_dtypes(rng):
    args = _inputs(rng, 4, 2, 32)
    v, s = merge_attn.optimized(*args)
    assert v.dtype == jnp.float32 and s.dtype == jnp.float32
