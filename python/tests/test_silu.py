"""Kernel 3 (silu_and_mul): Pallas variants vs pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, silu

TOL = dict(rtol=1e-5, atol=1e-5)


def _inputs(rng, b, d):
    return rng.standard_normal((b, 2 * d), dtype=np.float32)


@pytest.mark.parametrize("variant", [silu.baseline, silu.optimized])
def test_matches_oracle(rng, variant):
    xg = _inputs(rng, 8, 256)
    out = variant(xg)
    np.testing.assert_allclose(out, ref.silu_and_mul(xg), **TOL)


def test_variants_agree(rng):
    xg = _inputs(rng, 16, 512)
    np.testing.assert_allclose(silu.baseline(xg), silu.optimized(xg), **TOL)


def test_zero_gate_zero_output(rng):
    xg = _inputs(rng, 4, 256)
    xg[:, 256:] = 0.0
    np.testing.assert_allclose(silu.optimized(xg), 0.0, atol=1e-6)


def test_silu_saturation():
    """SiLU(z) -> z for large z, -> 0 for very negative z."""
    b, d = 4, 256
    xg = np.zeros((b, 2 * d), np.float32)
    xg[:, :d] = 30.0
    xg[:, d:] = 1.0
    np.testing.assert_allclose(silu.optimized(xg), 30.0, rtol=1e-5)
    xg[:, :d] = -30.0
    np.testing.assert_allclose(silu.optimized(xg), 0.0, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(
    b=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matches_oracle(b, d, seed):
    rng = np.random.default_rng(seed)
    xg = _inputs(rng, b, d)
    for variant in (silu.baseline, silu.optimized):
        np.testing.assert_allclose(
            variant(xg, block_rows=4), ref.silu_and_mul(xg), **TOL
        )


def test_block_rows_invariance(rng):
    xg = _inputs(rng, 16, 256)
    o1 = silu.optimized(xg, block_rows=2)
    o2 = silu.optimized(xg, block_rows=16)
    np.testing.assert_allclose(o1, o2, **TOL)
