"""L2 decode layer: variant equivalence and shape checks."""

import numpy as np

from compile import model

TOL = dict(rtol=2e-4, atol=2e-4)


def _cfg():
    return dict(batch=8, heads=4, head_dim=64, inter=256)


def test_decode_layer_shapes():
    cfg = _cfg()
    inputs = model.example_inputs(**cfg)
    out, r_new, s_out = model.decode_layer(*inputs.values(), variant="optimized")
    hidden = cfg["heads"] * cfg["head_dim"]
    assert out.shape == (cfg["batch"], hidden)
    assert r_new.shape == (cfg["batch"], hidden)
    assert s_out.shape == (cfg["batch"], cfg["heads"])


def test_variants_equivalent():
    """Baseline and optimized kernel stacks compute the same layer."""
    inputs = model.example_inputs(**_cfg())
    base = model.decode_layer(*inputs.values(), variant="baseline")
    opt = model.decode_layer(*inputs.values(), variant="optimized")
    for b, o in zip(base, opt):
        np.testing.assert_allclose(b, o, **TOL)


def test_outputs_finite():
    inputs = model.example_inputs(**_cfg(), seed=3)
    for t in model.decode_layer(*inputs.values(), variant="optimized"):
        assert np.all(np.isfinite(np.asarray(t)))


def test_deterministic():
    inputs = model.example_inputs(**_cfg())
    a = model.decode_layer(*inputs.values(), variant="optimized")
    b = model.decode_layer(*inputs.values(), variant="optimized")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
