"""AOT driver: lower every (kernel, variant, shape) to HLO *text*.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Outputs (under --outdir, default ../artifacts):
  <name>.hlo.txt   one per manifest entry
  manifest.json    input/output shapes+dtypes per entry, consumed by the
                   Rust artifact registry (rust/src/runtime/registry.rs)

Run via `make artifacts`; a no-op when inputs are unchanged (make-level
stamp). Python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import merge_attn, rmsnorm, silu


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _meta(specs):
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
    ]


# ---------------------------------------------------------------------------
# Manifest construction
# ---------------------------------------------------------------------------

# Per-kernel shape roles. `oracle` shapes are small (fast ground-truth
# validation on the Rust side); `serve` shapes feed the decode-layer
# serving pipeline.
MERGE_SHAPES = {"oracle": (8, 4, 64), "serve": (32, 8, 64)}
RMSNORM_SHAPES = {"oracle": (8, 256), "serve": (32, 512)}
SILU_SHAPES = {"oracle": (8, 256), "serve": (32, 1024)}  # (batch, D); in = 2D
SERVE_CFG = dict(batch=32, heads=8, head_dim=64, inter=1024)


def entries():
    """Yield (name, jitted_fn, input_specs, metadata) for every artifact."""
    variants = {"base": "baseline", "opt": "optimized"}

    for tag, variant in variants.items():
        fn = getattr(merge_attn, variant)
        for role, (s, h, d) in MERGE_SHAPES.items():
            specs = [
                _spec((s, h, d)),
                _spec((s, h)),
                _spec((s, h, d)),
                _spec((s, h)),
            ]
            yield (
                f"merge_{tag}_{role}",
                fn,
                specs,
                {
                    "kernel": "merge_attn_states_lse",
                    "variant": variant,
                    "role": role,
                },
            )

    for tag, variant in variants.items():
        fn = getattr(rmsnorm, variant)
        for role, (b, d) in RMSNORM_SHAPES.items():
            specs = [_spec((b, d)), _spec((b, d)), _spec((d,))]
            yield (
                f"rmsnorm_{tag}_{role}",
                fn,
                specs,
                {
                    "kernel": "fused_add_rmsnorm",
                    "variant": variant,
                    "role": role,
                },
            )

    for tag, variant in variants.items():
        fn = getattr(silu, variant)
        for role, (b, d) in SILU_SHAPES.items():
            specs = [_spec((b, 2 * d))]
            yield (
                f"silu_{tag}_{role}",
                fn,
                specs,
                {"kernel": "silu_and_mul", "variant": variant, "role": role},
            )

    cfg = SERVE_CFG
    hidden = cfg["heads"] * cfg["head_dim"]
    layer_specs = [
        _spec((cfg["batch"], hidden)),  # x
        _spec((cfg["batch"], hidden)),  # r
        _spec((cfg["batch"], cfg["heads"], cfg["head_dim"])),  # v_a
        _spec((cfg["batch"], cfg["heads"])),  # s_a
        _spec((cfg["batch"], cfg["heads"], cfg["head_dim"])),  # v_b
        _spec((cfg["batch"], cfg["heads"])),  # s_b
        _spec((hidden,)),  # w_norm
        _spec((hidden, hidden)),  # w_o
        _spec((hidden, 2 * cfg["inter"])),  # w_gateup
        _spec((cfg["inter"], hidden)),  # w_down
    ]
    for tag, variant in variants.items():

        def layer_fn(*args, _v=variant):
            return model.decode_layer(*args, variant=_v)

        yield (
            f"decode_layer_{tag}_serve",
            jax.jit(layer_fn),
            layer_specs,
            {"kernel": "decode_layer", "variant": variant, "role": "serve"},
        )


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--outdir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="substring filter on artifact names"
    )
    args = parser.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = []
    for name, fn, specs, meta in entries():
        if args.only and args.only not in name:
            continue
        lowered = fn.lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_meta = _meta(jax.tree_util.tree_leaves(lowered.out_info))
        manifest.append(
            {
                "name": name,
                "file": fname,
                **meta,
                "inputs": _meta(specs),
                "outputs": out_meta,
                "tuple_output": True,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest)} artifacts)")

    # Line-based twin for the Rust registry (the offline build carries no
    # JSON parser): name|file|kernel|variant|role|in=shape:dtype,...|out=...
    def fmt(metas):
        return ",".join(
            "x".join(str(d) for d in m["shape"]) + ":" + m["dtype"]
            for m in metas
        )

    tpath = os.path.join(args.outdir, "manifest.txt")
    with open(tpath, "w") as f:
        for e in manifest:
            f.write(
                "|".join(
                    [
                        e["name"],
                        e["file"],
                        e["kernel"],
                        e["variant"],
                        e["role"],
                        "in=" + fmt(e["inputs"]),
                        "out=" + fmt(e["outputs"]),
                    ]
                )
                + "\n"
            )
    print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
