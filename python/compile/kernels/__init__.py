"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

Each module exposes `baseline` and `optimized` jitted entry points plus the
pure-jnp oracles in `ref`.
"""

from . import merge_attn, ref, rmsnorm, silu  # noqa: F401
