"""Pallas implementations of merge_attn_states_lse (Kernel 1).

Two variants mirror the paper's Figure 2 case study, translated to TPU
(DESIGN.md §Hardware-Adaptation):

  baseline  — the mixing weights are materialized and re-derived at full
              [rows, H, D] rank, i.e. the exponentials/normalization are
              recomputed "per element" exactly like the un-hoisted CUDA
              loop body.
  optimized — the weights are computed once per (row, head) at [rows, H]
              rank and broadcast over the head dimension, leaving the
              element body a single fused multiply-add; rows are blocked
              so each grid step moves one contiguous tile HBM->VMEM.

Both run under interpret=True (CPU PJRT can not execute Mosaic
custom-calls) and are validated against ref.merge_attn_states_lse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MERGE_EPS

# Rows handled per grid step. 8 keeps VMEM usage tiny at every shape we AOT
# while still amortizing grid overhead; see DESIGN.md §Perf for the sweep.
DEFAULT_BLOCK_ROWS = 8


def _baseline_kernel(va_ref, sa_ref, vb_ref, sb_ref, vo_ref, so_ref):
    va = va_ref[...]
    vb = vb_ref[...]
    sa = sa_ref[...]
    sb = sb_ref[...]
    # Un-hoisted: broadcast the scores to full rank FIRST, then take the
    # exponentials / reciprocal at [rows, H, D] — the TPU rendition of
    # recomputing smax/wa/wb/inv inside the inner element loop (Fig. 2a).
    sa3 = jnp.broadcast_to(sa[:, :, None], va.shape)
    sb3 = jnp.broadcast_to(sb[:, :, None], vb.shape)
    m3 = jnp.maximum(sa3, sb3)
    wa3 = jnp.exp(sa3 - m3)
    wb3 = jnp.exp(sb3 - m3)
    inv3 = 1.0 / (wa3 + wb3 + MERGE_EPS)
    vo_ref[...] = (wa3 * inv3) * va + (wb3 * inv3) * vb
    # Score output (computed once per (row, head) even in the baseline —
    # the paper's baseline hot loop is only the V merge).
    m = jnp.maximum(sa, sb)
    wa = jnp.exp(sa - m)
    wb = jnp.exp(sb - m)
    so_ref[...] = m + jnp.log(wa + wb)


def _optimized_kernel(va_ref, sa_ref, vb_ref, sb_ref, vo_ref, so_ref):
    va = va_ref[...]
    vb = vb_ref[...]
    sa = sa_ref[...]
    sb = sb_ref[...]
    # Hoisted: all transcendental work happens once per (row, head) at
    # [rows, H] rank; the element body is one fused multiply-add (Fig. 2b).
    m = jnp.maximum(sa, sb)
    wa = jnp.exp(sa - m)
    wb = jnp.exp(sb - m)
    inv = 1.0 / (wa + wb + MERGE_EPS)
    a = (wa * inv)[:, :, None]
    b = (wb * inv)[:, :, None]
    vo_ref[...] = a * va + b * vb
    so_ref[...] = m + jnp.log(wa + wb)


def _call(kernel, v_a, s_a, v_b, s_b, block_rows):
    seq, heads, dim = v_a.shape
    rows = min(block_rows, seq)
    assert seq % rows == 0, f"seq={seq} not a multiple of block_rows={rows}"
    grid = (seq // rows,)
    v_spec = pl.BlockSpec((rows, heads, dim), lambda i: (i, 0, 0))
    s_spec = pl.BlockSpec((rows, heads), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[v_spec, s_spec, v_spec, s_spec],
        out_specs=[v_spec, s_spec],
        out_shape=[
            jax.ShapeDtypeStruct((seq, heads, dim), v_a.dtype),
            jax.ShapeDtypeStruct((seq, heads), s_a.dtype),
        ],
        interpret=True,
    )(v_a, s_a, v_b, s_b)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def baseline(v_a, s_a, v_b, s_b, block_rows=DEFAULT_BLOCK_ROWS):
    """Baseline merge_attn_states_lse: per-element weight recomputation."""
    v, s = _call(_baseline_kernel, v_a, s_a, v_b, s_b, block_rows)
    return v, s


@functools.partial(jax.jit, static_argnames=("block_rows",))
def optimized(v_a, s_a, v_b, s_b, block_rows=DEFAULT_BLOCK_ROWS):
    """Optimized merge_attn_states_lse: hoisted per-(row,head) weights."""
    v, s = _call(_optimized_kernel, v_a, s_a, v_b, s_b, block_rows)
    return v, s
