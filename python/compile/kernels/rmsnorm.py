"""Pallas implementations of fused_add_rmsnorm (Kernel 2).

Two variants mirror the paper's Figure 3 case study, translated to TPU
(DESIGN.md §Hardware-Adaptation):

  baseline  — the row reduction is a *serial chunk loop* (lax.fori_loop over
              fixed-size slices of the row), the TPU rendition of the
              shared-memory tree reduction that progressively idles lanes
              and synchronizes between steps.
  optimized — the reduction is a single register/VMEM-resident vectorized
              jnp.sum over the whole row tile (the VPU cross-lane analogue
              of the __shfl_down_sync warp reduction), and the division is
              replaced by reciprocal-multiply (rsqrt).

Both run under interpret=True and are validated against
ref.fused_add_rmsnorm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import RMSNORM_EPS

DEFAULT_BLOCK_ROWS = 8
# Chunk width of the baseline's serial reduction loop (must divide D).
BASELINE_CHUNK = 128


def _baseline_kernel(x_ref, r_ref, w_ref, y_ref, rn_ref, *, eps, chunk):
    x = x_ref[...]
    r = r_ref[...]
    w = w_ref[...]
    h = x + r
    rows, d = h.shape
    steps = d // chunk

    # Serial tree-reduction stand-in: accumulate sum-of-squares chunk by
    # chunk with a loop-carried accumulator (Fig. 3a: stepwise reduction
    # with a barrier per step).
    def body(i, acc):
        c = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        return acc + jnp.sum(c * c, axis=1)

    ss = jax.lax.fori_loop(0, steps, body, jnp.zeros((rows,), h.dtype))
    # Baseline normalizes with an explicit divide (no reciprocal trick).
    y_ref[...] = h / jnp.sqrt(ss / d + eps)[:, None] * w[None, :]
    rn_ref[...] = h


def _optimized_kernel(x_ref, r_ref, w_ref, y_ref, rn_ref, *, eps):
    x = x_ref[...]
    r = r_ref[...]
    w = w_ref[...]
    h = x + r
    d = h.shape[-1]
    # Register-resident vectorized reduction (Fig. 3b) + rsqrt
    # (reciprocal-multiply instead of divide).
    ss = jnp.sum(h * h, axis=1)
    inv = jax.lax.rsqrt(ss / d + eps)
    y_ref[...] = h * inv[:, None] * w[None, :]
    rn_ref[...] = h


def _specs(batch, d, rows):
    grid = (batch // rows,)
    row_spec = pl.BlockSpec((rows, d), lambda i: (i, 0))
    w_spec = pl.BlockSpec((d,), lambda i: (0,))
    return grid, row_spec, w_spec


@functools.partial(jax.jit, static_argnames=("block_rows",))
def baseline(x, r, w, block_rows=DEFAULT_BLOCK_ROWS):
    """Baseline fused_add_rmsnorm: serial chunked reduction, divide."""
    batch, d = x.shape
    rows = min(block_rows, batch)
    assert batch % rows == 0 and d % BASELINE_CHUNK == 0
    grid, row_spec, w_spec = _specs(batch, d, rows)
    kernel = functools.partial(
        _baseline_kernel, eps=RMSNORM_EPS, chunk=BASELINE_CHUNK
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, w_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((batch, d), x.dtype),
            jax.ShapeDtypeStruct((batch, d), x.dtype),
        ],
        interpret=True,
    )(x, r, w)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def optimized(x, r, w, block_rows=DEFAULT_BLOCK_ROWS):
    """Optimized fused_add_rmsnorm: vectorized reduction, rsqrt."""
    batch, d = x.shape
    rows = min(block_rows, batch)
    assert batch % rows == 0
    grid, row_spec, w_spec = _specs(batch, d, rows)
    kernel = functools.partial(_optimized_kernel, eps=RMSNORM_EPS)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, w_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((batch, d), x.dtype),
            jax.ShapeDtypeStruct((batch, d), x.dtype),
        ],
        interpret=True,
    )(x, r, w)
