"""Pure-jnp correctness oracles for the three SGLang kernels.

These are the ground truth every Pallas variant (and, transitively, every
Rust-side candidate kernel produced by the Astra agents) is validated
against.  They mirror Table 1 of the paper:

  merge_attn_states_lse :  V = (e^Sa Va + e^Sb Vb) / (e^Sa + e^Sb)
                           S = log(e^Sa + e^Sb)
  fused_add_rmsnorm     :  y = (x + r) / sqrt(mean((x+r)^2) + eps) * w
  silu_and_mul          :  out = SiLU(x) * g,  SiLU(z) = z / (1 + e^-z)

All I/O is float32 (the interchange dtype with the Rust PJRT runtime); the
half-precision memory-traffic story lives in the Rust IR / simulator layer.
"""

from __future__ import annotations

import jax.numpy as jnp

# Matches the epsilon the paper's Figure 2 baseline adds to the weight sum.
MERGE_EPS = 1e-12
RMSNORM_EPS = 1e-6


def merge_attn_states_lse(v_a, s_a, v_b, s_b):
    """Merge two partial attention states with their log-sum-exp scores.

    Args:
      v_a, v_b: [S, H, D] partial attention outputs.
      s_a, s_b: [S, H] log-sum-exp scores.
    Returns:
      (v_out [S, H, D], s_out [S, H])
    """
    m = jnp.maximum(s_a, s_b)
    w_a = jnp.exp(s_a - m)
    w_b = jnp.exp(s_b - m)
    inv = 1.0 / (w_a + w_b + MERGE_EPS)
    a = (w_a * inv)[:, :, None]
    b = (w_b * inv)[:, :, None]
    v_out = a * v_a + b * v_b
    s_out = m + jnp.log(w_a + w_b)
    return v_out, s_out


def fused_add_rmsnorm(x, r, w, eps=RMSNORM_EPS):
    """Residual-add + RMSNorm, SGLang semantics.

    Args:
      x: [B, D] hidden states.
      r: [B, D] residual.
      w: [D] norm weight.
    Returns:
      (y [B, D] normalized output, r_new [B, D] updated residual = x + r)
    """
    h = x + r
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * (1.0 / jnp.sqrt(var + eps)) * w[None, :]
    return y, h


def silu_and_mul(xg):
    """Fused SiLU-gate: input is [B, 2*D] with x = xg[:, :D], g = xg[:, D:]."""
    d = xg.shape[-1] // 2
    x = xg[:, :d]
    g = xg[:, d:]
    return (x / (1.0 + jnp.exp(-x))) * g
