"""Pallas implementations of silu_and_mul (Kernel 3).

Two variants mirror the paper's Figures 4-5 case study, translated to TPU
(DESIGN.md §Hardware-Adaptation):

  baseline  — processes the row in a serial chunk loop (the scalar-load
              analogue of Fig. 4a) and computes SiLU with an explicit
              division x / (1 + exp(-x)) (Fig. 5a).
  optimized — a single vectorized pass over the whole row tile (the
              half2/one-DMA analogue of Fig. 4b) with the division replaced
              by a reciprocal-multiply sequence (Fig. 5b).

Both run under interpret=True and are validated against ref.silu_and_mul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8
# Chunk width of the baseline's serial loop (must divide D).
BASELINE_CHUNK = 128


def _baseline_kernel(xg_ref, o_ref, *, d, chunk):
    xg = xg_ref[...]
    x = xg[:, :d]
    g = xg[:, d:]
    rows = x.shape[0]
    steps = d // chunk

    # Serial chunked pass with explicit division (Figs. 4a + 5a).
    def body(i, out):
        xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        gc = jax.lax.dynamic_slice_in_dim(g, i * chunk, chunk, axis=1)
        s = xc / (1.0 + jnp.exp(-xc))
        return jax.lax.dynamic_update_slice(out, s * gc, (0, i * chunk))

    o_ref[...] = jax.lax.fori_loop(
        0, steps, body, jnp.zeros((rows, d), x.dtype)
    )


def _optimized_kernel(xg_ref, o_ref, *, d):
    xg = xg_ref[...]
    x = xg[:, :d]
    g = xg[:, d:]
    # Whole-tile vectorized pass; reciprocal-multiply instead of divide
    # (Figs. 4b + 5b).
    s = x * (1.0 / (1.0 + jnp.exp(-x)))
    o_ref[...] = s * g


def _call(kernel, xg, d, rows):
    batch = xg.shape[0]
    grid = (batch // rows,)
    in_spec = pl.BlockSpec((rows, 2 * d), lambda i: (i, 0))
    out_spec = pl.BlockSpec((rows, d), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((batch, d), xg.dtype),
        interpret=True,
    )(xg)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def baseline(xg, block_rows=DEFAULT_BLOCK_ROWS):
    """Baseline silu_and_mul: serial chunk loop, explicit division."""
    batch, dd = xg.shape
    d = dd // 2
    rows = min(block_rows, batch)
    assert batch % rows == 0 and d % BASELINE_CHUNK == 0
    kernel = functools.partial(_baseline_kernel, d=d, chunk=BASELINE_CHUNK)
    return _call(kernel, xg, d, rows)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def optimized(xg, block_rows=DEFAULT_BLOCK_ROWS):
    """Optimized silu_and_mul: vectorized pass, reciprocal-multiply."""
    batch, dd = xg.shape
    d = dd // 2
    rows = min(block_rows, batch)
    assert batch % rows == 0
    kernel = functools.partial(_optimized_kernel, d=d)
    return _call(kernel, xg, d, rows)
