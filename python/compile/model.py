"""Layer-2 JAX model: a decode-layer compute graph composing the kernels.

This is the SGLang-reintegration stand-in (DESIGN.md §6): the three Astra
kernels embedded in the dataflow of one transformer decode step —

    h, r' = fused_add_rmsnorm(x, r, w_norm)          (Kernel 2)
    v, s  = merge_attn_states_lse(v_a, s_a, v_b, s_b) (Kernel 1, the
            two partial attention states of a chunked-prefill/split-KV step)
    attn  = v flattened per row, projected by w_o
    u     = (h + attn) @ w_gateup
    mlp   = silu_and_mul(u)                           (Kernel 3)
    out   = mlp @ w_down

`decode_layer` is lowered AOT for both kernel variants; the Rust serving
pipeline (rust/src/pipeline/) executes the artifacts via PJRT and measures
end-to-end latency/throughput, baseline vs optimized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import merge_attn, rmsnorm, silu


@functools.partial(jax.jit, static_argnames=("variant",))
def decode_layer(
    x, r, v_a, s_a, v_b, s_b, w_norm, w_o, w_gateup, w_down, variant="optimized"
):
    """One decode-layer step over a batch of requests.

    Shapes (B = batch of decode tokens, H = heads, D = head dim,
    Dh = hidden = H*D, Di = intermediate):
      x, r            [B, Dh]
      v_a, v_b        [B, H, D]   partial attention outputs
      s_a, s_b        [B, H]      partial log-sum-exp scores
      w_norm          [Dh]
      w_o             [Dh, Dh]
      w_gateup        [Dh, 2*Di]
      w_down          [Di, Dh]
    Returns:
      (out [B, Dh], r_new [B, Dh], s_out [B, H])
    """
    k = {
        "baseline": (merge_attn.baseline, rmsnorm.baseline, silu.baseline),
        "optimized": (merge_attn.optimized, rmsnorm.optimized, silu.optimized),
    }[variant]
    merge_fn, rmsnorm_fn, silu_fn = k

    h, r_new = rmsnorm_fn(x, r, w_norm)
    v, s_out = merge_fn(v_a, s_a, v_b, s_b)
    b = x.shape[0]
    attn = v.reshape(b, -1) @ w_o
    u = (h + attn) @ w_gateup
    mlp = silu_fn(u)
    out = mlp @ w_down
    return out, r_new, s_out


def example_inputs(batch=64, heads=8, head_dim=128, inter=2048, seed=0):
    """Deterministic example inputs for AOT lowering and tests."""
    hidden = heads * head_dim
    keys = jax.random.split(jax.random.PRNGKey(seed), 10)
    f = jnp.float32
    return dict(
        x=jax.random.normal(keys[0], (batch, hidden), f),
        r=jax.random.normal(keys[1], (batch, hidden), f),
        v_a=jax.random.normal(keys[2], (batch, heads, head_dim), f),
        s_a=jax.random.normal(keys[3], (batch, heads), f),
        v_b=jax.random.normal(keys[4], (batch, heads, head_dim), f),
        s_b=jax.random.normal(keys[5], (batch, heads), f),
        w_norm=1.0 + 0.1 * jax.random.normal(keys[6], (hidden,), f),
        w_o=jax.random.normal(keys[7], (hidden, hidden), f) / hidden**0.5,
        w_gateup=jax.random.normal(keys[8], (hidden, 2 * inter), f)
        / hidden**0.5,
        w_down=jax.random.normal(keys[9], (inter, hidden), f) / inter**0.5,
    )
